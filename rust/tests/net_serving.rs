//! Integration tests for the network serving front end (`chime::net`).
//!
//! The headline assertions (ISSUE 8 acceptance criteria):
//!
//! * **Deterministic loopback** — a fixed request set driven through
//!   `serve --listen` + real HTTP sockets yields a `ServeOutcome`
//!   canonical JSON **bit-identical** to the same requests run
//!   in-process via `Session::serve`, for the sim and the sharded
//!   2-package backends. Both sides parse the same decimal offset
//!   strings and scale by 1e9, so the arrival f64s (and everything
//!   derived from them) are bitwise equal.
//! * **SSE replay** — the event stream for one request replays the
//!   exact `ServeEvent` sequence a hand-driven `ServingSession`
//!   produces, frame for frame.
//!
//! Plus HTTP-layer robustness against hostile/malformed traffic and an
//! in-process `loadgen` end-to-end run, all against loopback listeners
//! on ephemeral ports.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use chime::api::{ArrivalProcess, BackendKind, ChimeError, ServeRequest, Session};
use chime::net::{outcome_to_json, LoadgenConfig, NetServer, ServeOpts};
use chime::util::Json;

/// (id, max_new_tokens, arrival offset in seconds — kept as the decimal
/// *string* so the wire body and the in-process request parse the same
/// spelling). Ids 2 and 3 share an arrival to exercise the
/// submission-order tiebreak; id 1 is a zero-token inline completion.
const FIXTURE: &[(u64, usize, &str)] = &[
    (0, 4, "0"),
    (1, 0, "0.0005"),
    (2, 6, "0.001"),
    (3, 2, "0.001"),
    (4, 4, "0.002"),
    (5, 3, "0.0025"),
];

fn make_session(kind: BackendKind, packages: usize) -> Result<Session, ChimeError> {
    Session::builder()
        .model("tiny")
        .text_tokens(8)
        .output_tokens(4)
        .image_size(64)
        .backend(kind)
        .packages(packages)
        .build()
}

fn spawn(kind: BackendKind, packages: usize, deterministic: bool) -> NetServer {
    NetServer::spawn(
        "127.0.0.1:0",
        move || make_session(kind, packages),
        ServeOpts { deterministic, ..ServeOpts::default() },
    )
    .expect("loopback ephemeral listener must come up")
}

/// One raw HTTP exchange (Connection: close, read to EOF).
fn raw_call(addr: SocketAddr, raw: &[u8]) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s.write_all(raw).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8(buf).unwrap();
    let (head, body) = text.split_once("\r\n\r\n").expect("response must have a header block");
    let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
    (status, body.to_string())
}

fn call(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut req = format!("{method} {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n");
    if let Some(b) = body {
        req.push_str(&format!("Content-Length: {}\r\n", b.len()));
    }
    req.push_str("\r\n");
    if let Some(b) = body {
        req.push_str(b);
    }
    raw_call(addr, req.as_bytes())
}

fn submit_fixture(addr: SocketAddr) {
    for (id, tokens, off) in FIXTURE {
        let body =
            format!(r#"{{"id": {id}, "max_new_tokens": {tokens}, "arrival_offset_s": {off}}}"#);
        let (status, reply) = call(addr, "POST", "/v1/submit", Some(&body));
        assert_eq!(status, 200, "submit {id}: {reply}");
    }
}

fn fixture_requests() -> Vec<ServeRequest> {
    FIXTURE
        .iter()
        .map(|&(id, tokens, off)| ServeRequest {
            id,
            prompt: vec![],
            image_seed: id,
            max_new_tokens: tokens,
            arrival_ns: off.parse::<f64>().unwrap() * 1e9,
        })
        .collect()
}

/// Read the full SSE stream for a request (terminates at the `done`
/// frame, after which the server closes the connection).
fn read_sse(addr: SocketAddr, id: u64) -> Vec<(String, String)> {
    let (_, body) = call(addr, "GET", &format!("/v1/stream/{id}"), None);
    let mut frames = Vec::new();
    let (mut event, mut data) = (None, None);
    for line in body.lines() {
        if line.is_empty() {
            if let (Some(e), Some(d)) = (event.take(), data.take()) {
                frames.push((e, d));
            }
        } else if let Some(v) = line.strip_prefix("event: ") {
            event = Some(v.to_string());
        } else if let Some(v) = line.strip_prefix("data: ") {
            data = Some(v.to_string());
        }
    }
    frames
}

fn shutdown_and_join(server: NetServer) -> chime::net::ServeSummary {
    let (status, _) = call(server.addr(), "POST", "/v1/shutdown", None);
    assert_eq!(status, 200);
    server.join().expect("engine thread must exit cleanly")
}

#[test]
fn deterministic_loopback_matches_in_process_session() {
    for (kind, packages) in [(BackendKind::Sim, 1), (BackendKind::Sharded, 2)] {
        let server = spawn(kind, packages, true);
        let addr = server.addr();
        submit_fixture(addr);
        let (status, wire) = call(addr, "POST", "/v1/finish", None);
        assert_eq!(status, 200, "{wire}");

        // The in-process reference: identical requests, identical
        // submission order, through the batch entry point.
        let mut session = make_session(kind, packages).unwrap();
        let out = session.serve(fixture_requests()).unwrap();
        let reference = outcome_to_json(&out).pretty();
        assert_eq!(wire, reference, "{kind:?}/{packages}p wire vs in-process outcome");
        assert_eq!(out.responses.len(), FIXTURE.len());

        // Finish is idempotent, byte for byte.
        let (status, again) = call(addr, "POST", "/v1/finish", None);
        assert_eq!(status, 200);
        assert_eq!(again, wire);
        // The session is closed to new work once finished.
        let (status, reply) = call(addr, "POST", "/v1/submit", Some(r#"{"id": 99}"#));
        assert_eq!(status, 400, "{reply}");
        shutdown_and_join(server);
    }
}

#[test]
fn sse_stream_replays_the_hand_driven_event_sequence() {
    let server = spawn(BackendKind::Sharded, 2, true);
    let addr = server.addr();
    submit_fixture(addr);
    let (status, _) = call(addr, "POST", "/v1/finish", None);
    assert_eq!(status, 200);
    let frames = read_sse(addr, 2);

    // Hand-drive the exact same protocol sequence in-process.
    let mut session = make_session(BackendKind::Sharded, 2).unwrap();
    let mut serving = session.open_serving().unwrap();
    let mut events = Vec::new();
    for req in fixture_requests() {
        events.extend(serving.submit(req));
    }
    events.extend(serving.drain().unwrap());
    let expected: Vec<(String, String)> = events
        .iter()
        .filter(|e| e.id() == 2)
        .map(|e| (e.kind().to_string(), e.to_json().compact()))
        .chain(std::iter::once(("done".to_string(), "{}".to_string())))
        .collect();
    assert!(expected.len() > 2, "request 2 must have a token stream");
    assert_eq!(frames, expected, "SSE must replay the hand-driven event sequence exactly");
    shutdown_and_join(server);
}

#[test]
fn http_layer_rejects_malformed_traffic_without_dying() {
    let server = spawn(BackendKind::Sim, 1, false);
    let addr = server.addr();

    // Garbage request line.
    let (status, body) = raw_call(addr, b"TOTAL GARBAGE\r\n\r\n");
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("error"), "{body}");
    // Unknown route, wrong methods (known routes answer with Allow).
    assert_eq!(call(addr, "GET", "/v2/nope", None).0, 404);
    assert_eq!(call(addr, "GET", "/v1/submit", None).0, 405);
    assert_eq!(call(addr, "DELETE", "/v1/metrics", None).0, 405);
    assert_eq!(call(addr, "DELETE", "/v1/stream/0", None).0, 405);
    // Oversized declared body, missing Content-Length.
    let (status, _) = raw_call(addr, b"POST /v1/submit HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n");
    assert_eq!(status, 413);
    let (status, _) = raw_call(addr, b"POST /v1/submit HTTP/1.1\r\n\r\n");
    assert_eq!(status, 411);
    // Body-level validation.
    assert_eq!(call(addr, "POST", "/v1/submit", Some("not json")).0, 400);
    assert_eq!(call(addr, "POST", "/v1/submit", Some(r#"{"max_new_tokenz": 4}"#)).0, 400);
    assert_eq!(call(addr, "GET", "/v1/stream/xyz", None).0, 400);
    assert_eq!(call(addr, "GET", "/v1/stream/42", None).0, 404);
    // A non-finite arrival offset is shed by the engine, not a crash.
    let (status, reply) =
        call(addr, "POST", "/v1/submit", Some(r#"{"id": 7, "arrival_offset_s": 1e999}"#));
    assert_eq!(status, 200, "{reply}");
    assert!(reply.contains("shed"), "{reply}");

    // A real request whose SSE client disconnects mid-stream: the
    // server must neither panic nor leak the session.
    let (status, reply) =
        call(addr, "POST", "/v1/submit", Some(r#"{"id": 0, "max_new_tokens": 6}"#));
    assert_eq!(status, 200, "{reply}");
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        s.write_all(b"GET /v1/stream/0 HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
            .unwrap();
        let mut first = [0u8; 16];
        s.read_exact(&mut first).unwrap(); // the stream is live...
    } // ...and the client hangs up here.

    // The live engine keeps ticking: request 0 completes and the server
    // still answers.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (status, body) = call(addr, "GET", "/v1/metrics", None);
        assert_eq!(status, 200);
        let json = Json::parse(&body).unwrap();
        if json.get("counts").get("completed").as_i64() == Some(1) {
            break;
        }
        assert!(Instant::now() < deadline, "request 0 never completed: {body}");
        std::thread::sleep(Duration::from_millis(10));
    }
    let (status, _) = call(addr, "POST", "/v1/finish", None);
    assert_eq!(status, 200);
    shutdown_and_join(server);
}

#[test]
fn metrics_reports_server_config_and_counts() {
    let server = spawn(BackendKind::Sharded, 2, true);
    let addr = server.addr();
    let (status, body) = call(addr, "GET", "/v1/metrics", None);
    assert_eq!(status, 200);
    let json = Json::parse(&body).unwrap();
    let info = json.get("server");
    assert_eq!(info.get("protocol").as_str(), Some("chime-serve/1"));
    assert_eq!(info.get("model").as_str(), Some("tiny"));
    assert_eq!(info.get("deterministic").as_bool(), Some(true));
    for key in ["backend", "memory", "topology"] {
        assert!(info.get(key).as_str().is_some(), "missing server.{key} in {body}");
    }
    assert_eq!(json.get("state").as_str(), Some("serving"));
    assert!(json.get("outcome").is_null());

    let (status, _) = call(
        addr,
        "POST",
        "/v1/submit",
        Some(r#"{"id": 0, "max_new_tokens": 2, "arrival_offset_s": 0}"#),
    );
    assert_eq!(status, 200);
    let (status, _) = call(addr, "POST", "/v1/finish", None);
    assert_eq!(status, 200);
    let (_, body) = call(addr, "GET", "/v1/metrics", None);
    let json = Json::parse(&body).unwrap();
    assert_eq!(json.get("state").as_str(), Some("finished"));
    assert_eq!(json.get("counts").get("submitted").as_i64(), Some(1));
    assert_eq!(json.get("counts").get("completed").as_i64(), Some(1));
    assert_eq!(json.get("outcome").get("metrics").get("completed").as_i64(), Some(1));
    let summary = shutdown_and_join(server);
    assert_eq!(summary.submitted, 1);
    assert_eq!(summary.completed, 1);
}

#[test]
fn prometheus_exposition_reconciles_with_the_finish_outcome() {
    // ISSUE 9 acceptance: the counters scraped from
    // `GET /v1/metrics?format=prometheus` must reconcile with the
    // `/v1/finish` ServeOutcome — same requests, same tokens.
    let server = spawn(BackendKind::Sharded, 2, true);
    let addr = server.addr();

    // Before any traffic: a well-formed exposition with zeroed counters
    // and the serving state flagged active.
    let (status, prom) = call(addr, "GET", "/v1/metrics?format=prometheus", None);
    assert_eq!(status, 200);
    assert!(prom.contains("# TYPE chime_requests_submitted_total counter"), "{prom}");
    assert!(prom.contains("chime_requests_submitted_total 0\n"), "{prom}");
    assert!(prom.contains("chime_server_state{state=\"serving\"} 1\n"), "{prom}");
    assert!(prom.ends_with('\n'), "exposition must end with a newline");

    submit_fixture(addr);
    let (status, wire) = call(addr, "POST", "/v1/finish", None);
    assert_eq!(status, 200, "{wire}");
    let outcome = Json::parse(&wire).unwrap();
    let completed = outcome.get("metrics").get("completed").as_i64().unwrap();
    let tokens = outcome.get("metrics").get("tokens").as_i64().unwrap();
    let expected_tokens: usize = FIXTURE.iter().map(|&(_, t, _)| t).sum();
    assert_eq!(completed as usize, FIXTURE.len());
    assert_eq!(tokens as usize, expected_tokens);

    let (status, prom) = call(addr, "GET", "/v1/metrics?format=prometheus", None);
    assert_eq!(status, 200);
    for needle in [
        format!("chime_requests_submitted_total {}\n", FIXTURE.len()),
        format!("chime_requests_admitted_total {completed}\n"),
        format!("chime_requests_completed_total {completed}\n"),
        format!("chime_requests_rejected_total 0\n"),
        format!("chime_tokens_total {tokens}\n"),
        "chime_server_state{state=\"finished\"} 1\n".to_string(),
        "chime_server_state{state=\"serving\"} 0\n".to_string(),
    ] {
        assert!(prom.contains(&needle), "missing {needle:?} in:\n{prom}");
    }

    // JSON stays the default (and the explicit spelling), unknown
    // formats are a 400 naming the accepted ones.
    let (status, body) = call(addr, "GET", "/v1/metrics", None);
    assert_eq!(status, 200);
    assert!(Json::parse(&body).is_ok(), "default stays JSON: {body}");
    let (status, body) = call(addr, "GET", "/v1/metrics?format=json", None);
    assert_eq!(status, 200);
    assert!(Json::parse(&body).is_ok(), "{body}");
    let (status, body) = call(addr, "GET", "/v1/metrics?format=xml", None);
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("prometheus"), "400 must name the accepted formats: {body}");
    shutdown_and_join(server);
}

#[test]
fn serve_trace_out_writes_a_deterministic_chrome_trace() {
    // ServeOpts::trace_out: the engine thread records the served session
    // and writes Chrome trace-event JSON at drain. Same fixture, same
    // seed -> byte-identical file (golden determinism).
    let dir = std::env::temp_dir();
    let run = |name: &str| -> String {
        let path = dir.join(name);
        let opts = ServeOpts {
            deterministic: true,
            trace_out: Some(path.clone()),
            ..ServeOpts::default()
        };
        let server =
            NetServer::spawn("127.0.0.1:0", move || make_session(BackendKind::Sharded, 2), opts)
                .expect("loopback ephemeral listener must come up");
        let addr = server.addr();
        submit_fixture(addr);
        let (status, _) = call(addr, "POST", "/v1/finish", None);
        assert_eq!(status, 200);
        shutdown_and_join(server);
        let text = std::fs::read_to_string(&path).expect("trace file must exist after join");
        let _ = std::fs::remove_file(&path);
        text
    };
    let (a, b) = (run("chime_net_trace_a.json"), run("chime_net_trace_b.json"));
    let json = Json::parse(&a).expect("trace must be valid JSON");
    let events = json.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(!events.is_empty(), "a served session must record events");
    // Perfetto-relevant shape: metadata names the processes/tracks, and
    // the serving instants are present.
    assert!(a.contains("\"process_name\""), "{a}");
    assert!(a.contains("\"completed\""), "{a}");
    assert_eq!(a, b, "same fixture, byte-identical trace export");
}

#[test]
fn loadgen_drives_a_live_server_end_to_end() {
    let server = spawn(BackendKind::Sim, 1, false);
    let cfg = LoadgenConfig {
        target: server.addr().to_string(),
        requests: 4,
        arrival: ArrivalProcess::Poisson { rate_per_s: 50.0 },
        seed: 7,
        max_new_tokens: 3,
        prompt_tokens: 4,
        shutdown: true,
        timeout: Duration::from_secs(30),
    };
    let report = chime::net::loadgen::run(&cfg).unwrap();
    assert!(report.errors.is_empty(), "{:?}", report.errors);
    assert_eq!(report.samples.len(), 4);
    assert!(report.samples.iter().all(|s| s.tokens == 3), "{:?}", report.samples);
    assert!(
        report.samples.iter().all(|s| s.ttft_ns.is_some() && s.latency_ns > 0.0),
        "{:?}",
        report.samples
    );
    for needle in ["TTFT", "TPOT", "latency", "p99 (ms)", "achieved: 4 requests"] {
        assert!(report.table.contains(needle), "missing {needle:?} in:\n{}", report.table);
    }
    let outcome = report.outcome.expect("shutdown mode fetches the outcome");
    assert_eq!(outcome.get("metrics").get("completed").as_i64(), Some(4));
    // The loadgen's shutdown POST stops the listener; join reports what
    // it served.
    let summary = server.join().unwrap();
    assert_eq!(summary.submitted, 4);
    assert_eq!(summary.completed, 4);
    assert_eq!(summary.tokens, 12);
}
