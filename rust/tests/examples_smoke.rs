//! Smoke tests for the repo-root examples so they cannot silently rot.
//!
//! `cargo test` already *compiles* every `[[example]]` target (that is
//! the compile half of the guarantee); these tests additionally locate
//! the built binaries and *run* `quickstart` (with a tiny workload via
//! `--text/--out`) and `vqa_serving --requests 2` end to end, asserting
//! they exit 0 and print their headline output.
//!
//! When a partial invocation (e.g. `cargo test --test golden_paper`)
//! skipped building examples, the tests report that and pass — mirroring
//! the artifact-gated runtime tests — rather than failing on a build-plan
//! detail.

use std::path::PathBuf;
use std::process::Command;

const EXAMPLES: [&str; 4] = ["quickstart", "vqa_serving", "seqlen_sweep", "endurance_study"];

/// Locate a built example binary under the active target directory,
/// preferring the profile this test binary itself was built with so a
/// stale binary from the other profile is never picked up first.
fn example_bin(name: &str) -> Option<PathBuf> {
    let target_root = std::env::var_os("CARGO_TARGET_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target"));
    let profiles = if cfg!(debug_assertions) {
        ["debug", "release"]
    } else {
        ["release", "debug"]
    };
    for profile in profiles {
        for suffix in ["", ".exe"] {
            let p = target_root
                .join(profile)
                .join("examples")
                .join(format!("{name}{suffix}"));
            if p.exists() {
                return Some(p);
            }
        }
    }
    None
}

fn run_example(name: &str, args: &[&str]) -> Option<std::process::Output> {
    let bin = match example_bin(name) {
        Some(b) => b,
        None => {
            eprintln!("skipping: example {name} not built in this invocation");
            return None;
        }
    };
    let out = Command::new(&bin)
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap_or_else(|e| panic!("spawning {}: {e}", bin.display()));
    assert!(
        out.status.success(),
        "example {name} {args:?} exited {:?}\nstdout:\n{}\nstderr:\n{}",
        out.status.code(),
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    Some(out)
}

#[test]
fn all_examples_compiled() {
    // `cargo test` builds every [[example]] (compile rot fails the build
    // itself). This guards the discovery layer: if ANY example binary is
    // present, the build plan included examples, so ALL four must be —
    // a partial set means an [[example]] entry or path went stale.
    let missing: Vec<&str> = EXAMPLES
        .iter()
        .copied()
        .filter(|name| example_bin(name).is_none())
        .collect();
    if missing.len() == EXAMPLES.len() {
        // Filtered invocation (e.g. `cargo test --test examples_smoke`)
        // that built no examples at all; nothing to check.
        eprintln!("skipping: no examples built in this invocation");
        return;
    }
    assert!(
        missing.is_empty(),
        "examples built this invocation, but these are missing from the \
         target dir (stale [[example]] entry or path?): {missing:?}"
    );
}

#[test]
fn quickstart_runs_with_tiny_workload() {
    let Some(out) = run_example("quickstart", &["--text", "16", "--out", "8"]) else {
        return;
    };
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("CHIME"), "quickstart output missing headline:\n{stdout}");
    assert!(stdout.contains("speedup"), "quickstart output missing speedup:\n{stdout}");
}

#[test]
fn vqa_serving_runs_small_request_stream() {
    let Some(out) = run_example("vqa_serving", &["--requests", "2"]) else {
        return;
    };
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("simulated CHIME serving"),
        "vqa_serving output missing simulated section:\n{stdout}"
    );
    assert!(stdout.contains("tok/s"), "vqa_serving output missing throughput:\n{stdout}");
    assert!(
        stdout.contains("sharded CHIME serving"),
        "vqa_serving output missing sharded scaling section:\n{stdout}"
    );
}
