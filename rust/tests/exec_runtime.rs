//! Integration tests for the parallel serving executor (DESIGN.md §15)
//! through the public `Session` API.
//!
//! Two contracts:
//!
//! 1. **Deterministic mode** — `Session::builder().threads(n)` with
//!    n > 1 runs the windowed executor drain, and its `ServeOutcome`
//!    serializes byte-identically (canonical JSON, every float in full)
//!    to the single-thread event loop across the whole deployment
//!    matrix: sim / dram-only / 2-package / 4-package × both memory
//!    fidelities × steal on/off.
//! 2. **Wall-clock mode** — `Session::serve_wall_clock` free-runs the
//!    executor over host time; its outcome promises conservation
//!    (admitted + rejected + shed == offered, one response per admitted
//!    request), not bit-reproducibility, and is rejected with a typed
//!    error on backends without a package dimension.

use chime::api::{BackendKind, ServeRequest, Session, SessionBuilder};
use chime::config::{MemoryFidelity, MllmConfig};
use chime::coordinator::{BatchPolicy, RoutePolicy, ServeOutcome};
use chime::util::Json;

/// Canonical JSON for a serve outcome: per-response floats in full plus
/// every order-dependent metric accumulation, so any reordering of the
/// completion stream shows up as a byte diff.
fn outcome_json(out: &ServeOutcome) -> String {
    let rows: Vec<Json> = out
        .responses
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("id", (r.id as i64).into()),
                ("tokens", r.tokens.len().into()),
                ("queue_ns", r.queue_ns.into()),
                ("ttft_ns", r.ttft_ns.into()),
                ("service_ns", r.service_ns.into()),
                ("energy_j", r.energy_j.into()),
            ])
        })
        .collect();
    Json::obj(vec![
        ("responses", Json::Arr(rows)),
        ("shed", Json::arr(out.shed.iter().map(|r| Json::from(r.id as i64)))),
        ("completed", (out.metrics.completed as i64).into()),
        ("admitted", (out.metrics.admitted as i64).into()),
        ("rejected", (out.metrics.rejected as i64).into()),
        ("shed_count", (out.metrics.shed as i64).into()),
        ("tokens", (out.metrics.tokens as i64).into()),
        ("steals", (out.metrics.steals as i64).into()),
        ("stolen_bytes", (out.metrics.stolen_bytes as i64).into()),
        ("steal_delay_ns", out.metrics.steal_delay_ns.into()),
        ("energy_j", out.metrics.energy_j.into()),
        ("span_ns", out.metrics.span_ns().into()),
        ("service_stddev", out.metrics.service.stddev().into()),
        ("tokens_per_s", out.metrics.tokens_per_s().into()),
    ])
    .pretty()
}

fn tiny_builder() -> SessionBuilder {
    Session::builder()
        .model_config(MllmConfig::tiny())
        .image_size(64)
        .text_tokens(8)
        .output_tokens(4)
}

/// Staggered arrivals with mixed decode budgets (including a zero-token
/// request), so the drain crosses several arrival windows and the
/// inline-completion path.
fn staggered_requests(n: usize) -> Vec<ServeRequest> {
    (0..n)
        .map(|i| ServeRequest {
            id: i as u64,
            prompt: vec![],
            image_seed: i as u64,
            max_new_tokens: [4, 2, 0, 6, 3, 5][i % 6],
            arrival_ns: i as f64 * 7.5e4,
        })
        .collect()
}

#[test]
fn executor_outcome_is_bit_identical_across_the_deployment_matrix() {
    // (backend, packages): sim maps 1 package onto the SimulatedServer
    // core; 2 and 4 packages run the sharded coordinator.
    let deployments = [
        (BackendKind::Sim, 1usize),
        (BackendKind::DramOnly, 1),
        (BackendKind::Sharded, 2),
        (BackendKind::Sharded, 4),
    ];
    let reqs = staggered_requests(12);
    for (kind, packages) in deployments {
        for fidelity in [MemoryFidelity::FirstOrder, MemoryFidelity::CycleAccurate] {
            for steal in [false, true] {
                if steal && packages < 2 {
                    continue; // stealing needs sibling packages
                }
                let run = |threads: usize| -> String {
                    let mut session = tiny_builder()
                        .backend(kind)
                        .packages(packages)
                        .route(RoutePolicy::LeastLoaded)
                        .batch(BatchPolicy { max_batch: 2, queue_capacity: 8 })
                        .memory_fidelity(fidelity)
                        .work_stealing(steal)
                        .threads(threads)
                        .build()
                        .unwrap();
                    outcome_json(&session.serve(reqs.clone()).unwrap())
                };
                let (seq, exec) = (run(1), run(4));
                assert_eq!(
                    seq, exec,
                    "executor drain diverged: {kind:?} packages {packages} \
                     {fidelity:?} steal {steal}"
                );
            }
        }
    }
}

#[test]
fn wall_clock_session_conserves_under_multi_thread_load() {
    let mut session = tiny_builder()
        .backend(BackendKind::Sharded)
        .packages(4)
        .route(RoutePolicy::LeastLoaded)
        .batch(BatchPolicy { max_batch: 2, queue_capacity: 16 })
        .threads(4)
        .build()
        .unwrap();
    let mut reqs = staggered_requests(24);
    reqs.push(ServeRequest {
        id: 99,
        prompt: vec![],
        image_seed: 99,
        max_new_tokens: 4,
        arrival_ns: f64::NAN, // malformed: must be shed, not lost
    });
    let offered = reqs.len() as u64;
    let report = session.serve_wall_clock(reqs, 4).unwrap();
    let m = &report.outcome.metrics;
    assert_eq!(m.offered(), offered, "conservation: every request accounted");
    assert_eq!(m.admitted + m.rejected + m.shed, offered);
    assert_eq!(m.shed, 1, "the NaN arrival is shed");
    assert_eq!(report.outcome.responses.len() as u64, m.admitted);
    assert_eq!(m.completed, m.admitted, "every admitted request completes");
    assert!(report.workers >= 1 && report.workers <= 4);
    assert!(report.wall_ns > 0.0 && report.wall_ns.is_finite());
    assert!(report.events >= m.completed, "at least one event per completion");
}

#[test]
fn wall_clock_mode_is_a_typed_error_on_sequential_backends() {
    for kind in [BackendKind::Jetson, BackendKind::Facil] {
        let mut session = Session::builder().backend(kind).build().unwrap();
        let err = session.serve_wall_clock(ServeRequest::burst(2, 4), 2).unwrap_err();
        assert_eq!(err.exit_code(), 1, "{kind:?}: {err}");
        assert!(
            err.to_string().contains("wall-clock"),
            "{kind:?} error names the unsupported feature: {err}"
        );
    }
}
