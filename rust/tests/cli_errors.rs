//! CLI error-path regression tests against the built `chime` binary.
//!
//! Locks the `api_redesign` error contract end to end:
//!
//! * a bad `--config` file exits 2 with a readable message (pre-refactor
//!   this was a `panic!("config: {e}")`);
//! * a typo'd flag (`--routee`) exits 2 with a did-you-mean suggestion
//!   (pre-refactor `Args::parse` silently swallowed it);
//! * unknown models/backends/experiments exit 2 with hints.
//!
//! Like `examples_smoke.rs`, the tests skip when a partial invocation did
//! not build the binary.

use std::path::PathBuf;
use std::process::{Command, Output};

/// Locate the built `chime` binary, preferring this test's own profile.
fn chime_bin() -> Option<PathBuf> {
    let target_root = std::env::var_os("CARGO_TARGET_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target"));
    let profiles = if cfg!(debug_assertions) {
        ["debug", "release"]
    } else {
        ["release", "debug"]
    };
    for profile in profiles {
        for suffix in ["", ".exe"] {
            let p = target_root.join(profile).join(format!("chime{suffix}"));
            if p.exists() {
                return Some(p);
            }
        }
    }
    None
}

fn run_chime(args: &[&str]) -> Option<Output> {
    let bin = match chime_bin() {
        Some(b) => b,
        None => {
            eprintln!("skipping: chime binary not built in this invocation");
            return None;
        }
    };
    Some(
        Command::new(&bin)
            .args(args)
            .current_dir(env!("CARGO_MANIFEST_DIR"))
            .output()
            .unwrap_or_else(|e| panic!("spawning {}: {e}", bin.display())),
    )
}

fn stderr_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).to_string()
}

#[test]
fn garbage_config_file_exits_2_with_readable_message() {
    // Regression: main.rs used to `panic!("config: {e}")` here.
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cli_errors_garbage_config.json");
    std::fs::write(&path, "{ this is not json ]").unwrap();
    let Some(out) = run_chime(&["simulate", "--model", "tiny", "--config", path.to_str().unwrap()])
    else {
        return;
    };
    std::fs::remove_file(&path).ok();
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    let err = stderr_of(&out);
    assert!(err.contains("config"), "message not readable:\n{err}");
    assert!(!err.contains("panicked"), "config errors must not panic:\n{err}");
}

#[test]
fn missing_config_file_exits_2() {
    let Some(out) = run_chime(&["simulate", "--config", "/nonexistent/chime.json"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("config"));
}

#[test]
fn misspelled_flag_exits_2_with_suggestion() {
    // Regression: `--routee` was silently swallowed pre-refactor.
    let Some(out) = run_chime(&["serve", "--routee", "ll", "--requests", "1"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    let err = stderr_of(&out);
    assert!(err.contains("--routee"), "must name the bad flag:\n{err}");
    assert!(err.contains("did you mean --route?"), "must suggest the fix:\n{err}");
}

#[test]
fn misspelled_flag_is_rejected_on_every_subcommand() {
    for cmd in ["info", "simulate", "serve", "loadgen", "sweep", "results", "parity"] {
        let Some(out) = run_chime(&[cmd, "--completely-bogus-flag"]) else {
            return;
        };
        assert_eq!(
            out.status.code(),
            Some(2),
            "{cmd} accepted a bogus flag; stderr:\n{}",
            stderr_of(&out)
        );
        assert!(stderr_of(&out).contains("--completely-bogus-flag"), "{cmd}");
    }
}

#[test]
fn non_numeric_values_exit_2_not_panic() {
    // Regression: pre-refactor these hit panic! in Args::get_usize /
    // get_f64 and died with exit 101 and a backtrace.
    for argv in [
        ["simulate", "--model", "tiny", "--out", "abc"].as_slice(),
        ["serve", "--requests", "abc"].as_slice(),
        ["serve", "--rate", "fast"].as_slice(),
        ["serve", "--packages", "two"].as_slice(),
    ] {
        let Some(out) = run_chime(argv) else {
            return;
        };
        assert_eq!(
            out.status.code(),
            Some(2),
            "{argv:?}; stderr:\n{}",
            stderr_of(&out)
        );
        let err = stderr_of(&out);
        assert!(err.contains("expects a"), "{argv:?}: {err}");
        assert!(!err.contains("panicked"), "{argv:?} panicked:\n{err}");
    }
}

#[test]
fn unknown_model_exits_2_with_hint() {
    let Some(out) = run_chime(&["simulate", "--model", "fastvlm-9b"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2));
    let err = stderr_of(&out);
    assert!(err.contains("unknown model"), "{err}");
    assert!(err.contains("fastvlm-0.6b"), "hint must list models:\n{err}");
}

#[test]
fn unknown_backend_and_route_exit_2() {
    let Some(out) = run_chime(&["serve", "--backend", "gpu"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr_of(&out).contains("unknown backend"));

    let Some(out) = run_chime(&["serve", "--route", "zigzag", "--requests", "1"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr_of(&out).contains("unknown route"));
}

#[test]
fn unknown_experiment_and_command_exit_2() {
    let Some(out) = run_chime(&["results", "--fig", "99"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr_of(&out).contains("unknown experiment"));

    let Some(out) = run_chime(&["frobnicate"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr_of(&out).contains("unknown command"));
}

#[test]
fn malformed_arrival_exits_2() {
    // The --arrival spec grammar is burst | poisson:<rps> | trace:<file>;
    // every malformed spelling is a usage error, never a silent default.
    for argv in [
        ["serve", "--arrival", "fourier", "--requests", "1"].as_slice(),
        ["serve", "--arrival", "poisson", "--requests", "1"].as_slice(),
        ["serve", "--arrival", "poisson:", "--requests", "1"].as_slice(),
        ["serve", "--arrival", "poisson:fast", "--requests", "1"].as_slice(),
        ["serve", "--arrival", "poisson:-2", "--requests", "1"].as_slice(),
        ["serve", "--arrival", "trace:", "--requests", "1"].as_slice(),
        ["serve", "--arrival", "trace:/nonexistent/chime-trace.json", "--model", "tiny",
         "--text", "8", "--out", "4"].as_slice(),
        ["serve", "--arrival"].as_slice(), // value-less flag
    ] {
        let Some(out) = run_chime(argv) else {
            return;
        };
        assert_eq!(out.status.code(), Some(2), "{argv:?}; stderr:\n{}", stderr_of(&out));
        let err = stderr_of(&out);
        assert!(err.contains("arrival"), "{argv:?}: {err}");
        assert!(!err.contains("panicked"), "{argv:?} panicked:\n{err}");
    }
    // The unknown-process path names the accepted spellings.
    let Some(out) = run_chime(&["serve", "--arrival", "uniform", "--requests", "1"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr_of(&out).contains("poisson"), "{}", stderr_of(&out));
    // --rate and --arrival conflict (rate is shorthand for poisson).
    let Some(out) = run_chime(&["serve", "--arrival", "burst", "--rate", "4", "--requests", "1"])
    else {
        return;
    };
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr_of(&out).contains("shorthand"), "{}", stderr_of(&out));
}

#[test]
fn malformed_steal_exits_2() {
    for argv in [
        ["serve", "--steal", "maybe", "--requests", "1"].as_slice(),
        ["serve", "--steal"].as_slice(), // value-less flag
        // Stealing needs sibling packages: rejected on sequential backends.
        ["serve", "--backend", "jetson", "--steal", "on", "--requests", "1"].as_slice(),
    ] {
        let Some(out) = run_chime(argv) else {
            return;
        };
        assert_eq!(out.status.code(), Some(2), "{argv:?}; stderr:\n{}", stderr_of(&out));
        let err = stderr_of(&out);
        assert!(err.contains("steal"), "{argv:?}: {err}");
        assert!(!err.contains("panicked"), "{argv:?} panicked:\n{err}");
    }
}

#[test]
fn arrival_and_steal_happy_paths_exit_0() {
    // burst + steal on the sharded simulator.
    let Some(out) = run_chime(&[
        "serve", "--model", "tiny", "--text", "8", "--out", "4", "--arrival", "burst",
        "--steal", "on", "--packages", "2", "--requests", "4", "--tokens", "3",
    ]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(0), "stderr:\n{}", stderr_of(&out));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("steal on"), "{stdout}");
    assert!(stdout.contains("work steals:"), "{stdout}");

    // A trace file drives arrivals and per-request token budgets.
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cli_errors_arrival_trace.json");
    std::fs::write(&path, r#"[0, 0.0001, {"arrival_s": 0.0002, "tokens": 2}]"#).unwrap();
    let trace = format!("trace:{}", path.display());
    let Some(out) = run_chime(&[
        "serve", "--model", "tiny", "--text", "8", "--out", "4", "--arrival", &trace,
        "--tokens", "3",
    ]) else {
        return;
    };
    std::fs::remove_file(&path).ok();
    assert_eq!(out.status.code(), Some(0), "stderr:\n{}", stderr_of(&out));
    // 3 trace entries: 2 x 3 tokens + 1 x 2 tokens = 8 generated tokens.
    assert!(String::from_utf8_lossy(&out.stdout).contains("8 tokens"), "{:?}", out.stdout);
}

#[test]
fn unknown_memory_fidelity_exits_2_with_hint() {
    for argv in [
        ["simulate", "--model", "tiny", "--memory", "cyccle"].as_slice(),
        ["serve", "--requests", "1", "--memory", "dramsim"].as_slice(),
        ["sweep", "--memory", "approximate"].as_slice(),
    ] {
        let Some(out) = run_chime(argv) else {
            return;
        };
        assert_eq!(out.status.code(), Some(2), "{argv:?}; stderr:\n{}", stderr_of(&out));
        let err = stderr_of(&out);
        assert!(err.contains("unknown memory fidelity"), "{argv:?}: {err}");
        assert!(err.contains("first-order"), "hint must list fidelities:\n{err}");
    }
}

#[test]
fn unknown_topology_exits_2_with_hint() {
    // Value typos are unknown-name errors listing the accepted fabrics.
    for argv in [
        ["serve", "--requests", "1", "--topology", "rign"].as_slice(),
        ["simulate", "--model", "tiny", "--topology", "torus"].as_slice(),
        ["sweep", "--topology", "star"].as_slice(),
    ] {
        let Some(out) = run_chime(argv) else {
            return;
        };
        assert_eq!(out.status.code(), Some(2), "{argv:?}; stderr:\n{}", stderr_of(&out));
        let err = stderr_of(&out);
        assert!(err.contains("unknown topology"), "{argv:?}: {err}");
        assert!(err.contains("ring"), "hint must list fabrics:\n{err}");
        assert!(!err.contains("panicked"), "{argv:?} panicked:\n{err}");
    }
    // A value-less flag is a usage error naming the grammar.
    let Some(out) = run_chime(&["serve", "--requests", "1", "--topology"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("point-to-point"), "{}", stderr_of(&out));
    // A flag typo gets the edit-distance suggestion.
    let Some(out) = run_chime(&["serve", "--topolgy", "ring", "--requests", "1"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    let err = stderr_of(&out);
    assert!(err.contains("--topolgy"), "must name the bad flag:\n{err}");
    assert!(err.contains("did you mean --topology?"), "must suggest the fix:\n{err}");
}

#[test]
fn routed_topology_on_fabricless_backend_exits_2() {
    // Same contract as --memory cycle: a routed fabric on a backend with
    // no simulated chiplets is a usage error, not a silent no-op.
    let Some(out) =
        run_chime(&["serve", "--backend", "jetson", "--topology", "ring", "--requests", "1"])
    else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("fabric"), "{}", stderr_of(&out));
}

#[test]
fn routed_topology_steal_serve_exits_0() {
    let Some(out) = run_chime(&[
        "serve", "--model", "tiny", "--text", "8", "--out", "4", "--arrival", "poisson:8",
        "--steal", "on", "--packages", "4", "--topology", "ring", "--requests", "8",
        "--tokens", "16",
    ]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(0), "stderr:\n{}", stderr_of(&out));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("ring fabric"), "{stdout}");
    assert!(stdout.contains("work steals:"), "{stdout}");
}

#[test]
fn cycle_fidelity_on_memoryless_backend_exits_2() {
    // Same contract as the library path: --memory cycle on a backend with
    // no simulated chiplet memory is a usage error, not a silent no-op.
    let Some(out) =
        run_chime(&["serve", "--backend", "jetson", "--memory", "cycle", "--requests", "1"])
    else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("chiplet memory"), "{}", stderr_of(&out));
}

#[test]
fn cycle_fidelity_simulate_exits_0() {
    let Some(out) = run_chime(&[
        "simulate", "--model", "tiny", "--out", "4", "--text", "8", "--memory", "cycle",
        "--json",
    ]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(0), "stderr:\n{}", stderr_of(&out));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("\"mode\": \"chime+cycle\""), "{stdout}");
}

#[test]
fn malformed_listen_addrs_exit_2() {
    // The --listen grammar is HOST:PORT; every malformed spelling is a
    // usage error naming the expected shape, never a bind attempt.
    for argv in [
        ["serve", "--listen"].as_slice(), // value-less flag
        ["serve", "--listen", "not-an-addr"].as_slice(),
        ["serve", "--listen", "127.0.0.1:notaport"].as_slice(),
        ["serve", "--listen", "127.0.0.1"].as_slice(), // port missing
    ] {
        let Some(out) = run_chime(argv) else {
            return;
        };
        assert_eq!(out.status.code(), Some(2), "{argv:?}; stderr:\n{}", stderr_of(&out));
        let err = stderr_of(&out);
        assert!(err.contains("listen"), "{argv:?}: {err}");
        assert!(err.contains("HOST:PORT"), "must name the grammar:\n{err}");
        assert!(!err.contains("panicked"), "{argv:?} panicked:\n{err}");
    }
    // Batch-mode load-shaping flags conflict with the listener, which
    // takes arrivals from the wire; the message routes to `chime loadgen`.
    for flag in ["--arrival", "--requests"] {
        let Some(out) = run_chime(&["serve", "--listen", "127.0.0.1:0", flag, "poisson:4"]) else {
            return;
        };
        assert_eq!(out.status.code(), Some(2), "{flag}; stderr:\n{}", stderr_of(&out));
        assert!(stderr_of(&out).contains("loadgen"), "{flag}: {}", stderr_of(&out));
    }
    // Listener-only flags are rejected in batch mode.
    let Some(out) = run_chime(&["serve", "--deterministic", "--requests", "1"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("--listen"), "{}", stderr_of(&out));
    // A flag typo gets the edit-distance suggestion.
    let Some(out) = run_chime(&["serve", "--listn", "127.0.0.1:0"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("did you mean --listen?"), "{}", stderr_of(&out));
}

#[test]
fn malformed_loadgen_target_exits_2() {
    for argv in [
        ["loadgen"].as_slice(), // --target is required
        ["loadgen", "--target"].as_slice(),
        ["loadgen", "--target", "not-an-addr"].as_slice(),
        ["loadgen", "--target", "127.0.0.1:notaport"].as_slice(),
    ] {
        let Some(out) = run_chime(argv) else {
            return;
        };
        assert_eq!(out.status.code(), Some(2), "{argv:?}; stderr:\n{}", stderr_of(&out));
        let err = stderr_of(&out);
        assert!(err.contains("target"), "{argv:?}: {err}");
        assert!(!err.contains("panicked"), "{argv:?} panicked:\n{err}");
    }
    // A flag typo gets the edit-distance suggestion.
    let Some(out) = run_chime(&["loadgen", "--tagret", "127.0.0.1:80"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("did you mean --target?"), "{}", stderr_of(&out));
    // A bad timeout is a usage error too.
    let Some(out) = run_chime(&["loadgen", "--target", "127.0.0.1:80", "--timeout-s", "-5"])
    else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("timeout"), "{}", stderr_of(&out));
}

#[test]
fn loadgen_dead_target_exits_1_as_runtime_error() {
    // A well-formed address nobody listens on is a runtime failure
    // (exit 1), not a usage error: the command line was fine.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);
    let Some(out) = run_chime(&["loadgen", "--target", &addr, "--requests", "1"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(1), "stderr:\n{}", stderr_of(&out));
    let err = stderr_of(&out);
    assert!(err.contains("unreachable"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn observability_value_flags_reject_valueless_spellings() {
    // --trace-out / --profile / --json (loadgen) are value flags: the
    // value-less spelling is a usage error naming the expected value,
    // caught before any work (or any socket) happens.
    for argv in [
        ["simulate", "--model", "tiny", "--trace-out"].as_slice(),
        ["serve", "--requests", "1", "--trace-out"].as_slice(),
        ["serve", "--listen", "127.0.0.1:0", "--trace-out"].as_slice(),
        ["bench", "--quick", "--profile"].as_slice(),
        ["loadgen", "--target", "127.0.0.1:80", "--json"].as_slice(),
    ] {
        let Some(out) = run_chime(argv) else {
            return;
        };
        assert_eq!(out.status.code(), Some(2), "{argv:?}; stderr:\n{}", stderr_of(&out));
        let err = stderr_of(&out);
        assert!(err.contains("expects a file path"), "{argv:?}: {err}");
        assert!(!err.contains("panicked"), "{argv:?} panicked:\n{err}");
    }
}

#[test]
fn observability_flag_typos_exit_2_with_suggestion() {
    let Some(out) = run_chime(&["simulate", "--model", "tiny", "--trace-ouy", "t.json"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    let err = stderr_of(&out);
    assert!(err.contains("--trace-ouy"), "must name the bad flag:\n{err}");
    assert!(err.contains("did you mean --trace-out?"), "must suggest the fix:\n{err}");

    let Some(out) = run_chime(&["bench", "--quick", "--profle", "h.json"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("did you mean --profile?"), "{}", stderr_of(&out));
}

#[test]
fn trace_out_usage_conflicts_exit_2() {
    // --trace-out records one model's run: it conflicts with --all.
    let Some(out) = run_chime(&["simulate", "--all", "--trace-out", "t.json"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("single --model"), "{}", stderr_of(&out));
    // Backends without a simulator record no trace: rejected, not an
    // empty file.
    let Some(out) =
        run_chime(&["serve", "--backend", "jetson", "--trace-out", "t.json", "--requests", "1"])
    else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("records no trace"), "{}", stderr_of(&out));
}

#[test]
fn unwritable_trace_out_exits_1_as_runtime_error() {
    // The command line is fine; the filesystem refuses. Runtime failure
    // (exit 1), after the simulation itself succeeded.
    let Some(out) = run_chime(&[
        "simulate", "--model", "tiny", "--out", "4", "--text", "8",
        "--trace-out", "/nonexistent-chime-dir/trace.json",
    ]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(1), "stderr:\n{}", stderr_of(&out));
    let err = stderr_of(&out);
    assert!(err.contains("writing trace"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn trace_out_simulate_writes_a_chrome_trace() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cli_errors_simulate_trace.json");
    let Some(out) = run_chime(&[
        "simulate", "--model", "tiny", "--out", "4", "--text", "8", "--memory", "cycle",
        "--trace-out", path.to_str().unwrap(),
    ]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(0), "stderr:\n{}", stderr_of(&out));
    assert!(String::from_utf8_lossy(&out.stdout).contains("wrote trace"), "{:?}", out.stdout);
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(text.contains("\"traceEvents\""), "{text}");
    assert!(text.contains("\"process_name\""), "{text}");
    // The inference phases land on the coordinator track.
    assert!(text.contains("\"decode\""), "{text}");
}

#[test]
fn malformed_threads_exits_2() {
    // --threads is a value flag: value-less, zero, and non-numeric
    // spellings are usage errors on every subcommand that takes it.
    for argv in [
        ["serve", "--requests", "1", "--threads"].as_slice(), // value-less
        ["serve", "--requests", "1", "--threads", "0"].as_slice(),
        ["serve", "--requests", "1", "--threads", "many"].as_slice(),
        ["simulate", "--model", "tiny", "--threads", "0"].as_slice(),
        ["serve", "--listen", "127.0.0.1:0", "--threads", "0"].as_slice(),
        ["bench", "--quick", "--threads", "0"].as_slice(),
    ] {
        let Some(out) = run_chime(argv) else {
            return;
        };
        assert_eq!(out.status.code(), Some(2), "{argv:?}; stderr:\n{}", stderr_of(&out));
        let err = stderr_of(&out);
        assert!(err.contains("threads"), "{argv:?}: {err}");
        assert!(!err.contains("panicked"), "{argv:?} panicked:\n{err}");
    }
    // A flag typo gets the edit-distance suggestion.
    let Some(out) = run_chime(&["serve", "--thread", "4", "--requests", "1"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    let err = stderr_of(&out);
    assert!(err.contains("--thread"), "must name the bad flag:\n{err}");
    assert!(err.contains("did you mean --threads?"), "must suggest the fix:\n{err}");
    // Executor threads need the simulator's package dimension.
    let Some(out) =
        run_chime(&["serve", "--backend", "jetson", "--threads", "4", "--requests", "1"])
    else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("sequential stream"), "{}", stderr_of(&out));
}

#[test]
fn wall_mode_usage_conflicts_exit_2() {
    // --wall free-runs over host time: no deterministic virtual timeline
    // to trace, and work migration happens in the executor's deques.
    let Some(out) = run_chime(&[
        "serve", "--wall", "--trace-out", "t.json", "--requests", "1",
    ]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    let err = stderr_of(&out);
    assert!(err.contains("--wall") && err.contains("--trace-out"), "{err}");

    let Some(out) = run_chime(&["serve", "--wall", "--steal", "on", "--requests", "1"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("deques"), "{}", stderr_of(&out));

    // Sequential backends have no executor to free-run.
    let Some(out) = run_chime(&["serve", "--backend", "jetson", "--wall", "--requests", "1"])
    else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("sequential stream"), "{}", stderr_of(&out));

    // The listener already runs wall-clock against wire arrivals.
    let Some(out) = run_chime(&["serve", "--listen", "127.0.0.1:0", "--wall"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(2), "stderr:\n{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("--listen"), "{}", stderr_of(&out));
}

#[test]
fn threads_and_wall_happy_paths_exit_0() {
    // Deterministic executor drain: same output contract as --threads 1.
    let Some(out) = run_chime(&[
        "serve", "--model", "tiny", "--text", "8", "--out", "4", "--packages", "2",
        "--requests", "4", "--tokens", "3", "--threads", "2",
    ]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(0), "stderr:\n{}", stderr_of(&out));
    assert!(String::from_utf8_lossy(&out.stdout).contains("reqs completed"), "{:?}", out.stdout);

    // Free-running wall-clock mode prints the host counters.
    let Some(out) = run_chime(&[
        "serve", "--model", "tiny", "--text", "8", "--out", "4", "--packages", "2",
        "--requests", "4", "--tokens", "3", "--threads", "2", "--wall",
    ]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(0), "stderr:\n{}", stderr_of(&out));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("wall-clock CHIME serving"), "{stdout}");
    assert!(stdout.contains("events/s"), "{stdout}");
}

#[test]
fn happy_paths_still_exit_0() {
    let Some(out) = run_chime(&["info", "--models"]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(0), "stderr:\n{}", stderr_of(&out));
    assert!(String::from_utf8_lossy(&out.stdout).contains("fastvlm-0.6b"));

    let Some(out) = run_chime(&[
        "simulate", "--model", "tiny", "--out", "4", "--text", "8", "--json",
    ]) else {
        return;
    };
    assert_eq!(out.status.code(), Some(0), "stderr:\n{}", stderr_of(&out));
    assert!(String::from_utf8_lossy(&out.stdout).contains("\"tps\""));
}
