//! Integration tests: the PJRT functional runtime against the AOT
//! artifacts. These tests skip (pass trivially) when `make artifacts`
//! has not run, so `cargo test` stays green pre-build; CI runs
//! `make artifacts` first (see Makefile `test` target).

use chime::runtime::{FunctionalMllm, Manifest};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

#[test]
fn manifest_signatures_consistent() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    let cfg = &m.config;
    let dec = m.entry("decode_step").unwrap();
    assert_eq!(dec.inputs.len(), 4);
    let kv = &dec.inputs[2];
    assert_eq!(
        kv.shape,
        vec![cfg.n_layers, cfg.n_heads, cfg.max_len, cfg.d_head]
    );
    let pre = m.entry("prefill").unwrap();
    assert_eq!(pre.outputs[0].shape, vec![cfg.vocab]);
    let ve = m.entry("vision_encoder").unwrap();
    assert_eq!(ve.inputs[0].shape, vec![cfg.img_size, cfg.img_size, cfg.img_channels]);
}

#[test]
fn parity_with_python_oracle() {
    // THE cross-layer correctness test: rust PJRT greedy decode must
    // reproduce python's recorded token sequence bit-for-bit.
    let Some(dir) = artifacts() else { return };
    let mllm = FunctionalMllm::load(&dir).unwrap();
    mllm.verify_parity().unwrap();
}

#[test]
fn smoke_graph_matches_staged_pipeline() {
    // model.hlo.txt (single fused graph) and the staged entry points must
    // agree on the first greedy token.
    let Some(dir) = artifacts() else { return };
    let mllm = FunctionalMllm::load(&dir).unwrap();
    let image = mllm.manifest.synthetic_image();
    let prompt = mllm.manifest.parity.prompt.clone();
    let smoke_tok = mllm.smoke(&image, &prompt).unwrap();
    let gen = mllm.generate(&image, &prompt, 1).unwrap();
    assert_eq!(smoke_tok, gen.tokens[0]);
    assert_eq!(smoke_tok, mllm.manifest.parity.expected_tokens[0]);
}

#[test]
fn generation_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let mllm = FunctionalMllm::load(&dir).unwrap();
    let image = mllm.manifest.synthetic_image();
    let prompt = mllm.manifest.parity.prompt.clone();
    let a = mllm.generate(&image, &prompt, 6).unwrap();
    let b = mllm.generate(&image, &prompt, 6).unwrap();
    assert_eq!(a.tokens, b.tokens);
}

#[test]
fn generation_depends_on_image() {
    // Multimodality must be live in the compiled artifacts too.
    let Some(dir) = artifacts() else { return };
    let mllm = FunctionalMllm::load(&dir).unwrap();
    let prompt = mllm.manifest.parity.prompt.clone();
    let img_a = mllm.manifest.synthetic_image();
    let img_b: Vec<f32> = img_a.iter().map(|v| -v).collect();
    let a = mllm.smoke(&img_a, &prompt).unwrap();
    let b = mllm.smoke(&img_b, &prompt).unwrap();
    // Logits must differ; argmax usually does for an inverted image. If
    // argmax coincides, at least full generations should diverge.
    if a == b {
        let ga = mllm.generate(&img_a, &prompt, 8).unwrap();
        let gb = mllm.generate(&img_b, &prompt, 8).unwrap();
        assert_ne!(ga.tokens, gb.tokens, "image input appears dead");
    }
}

#[test]
fn rejects_malformed_inputs() {
    let Some(dir) = artifacts() else { return };
    let mllm = FunctionalMllm::load(&dir).unwrap();
    let image = mllm.manifest.synthetic_image();
    // Wrong prompt length.
    assert!(mllm.generate(&image, &[1, 2, 3], 2).is_err());
    // Wrong image size.
    assert!(mllm.generate(&image[..10], &mllm.manifest.parity.prompt, 2).is_err());
}

#[test]
fn kv_capacity_bounds_generation() {
    let Some(dir) = artifacts() else { return };
    let mllm = FunctionalMllm::load(&dir).unwrap();
    let cfg = &mllm.manifest.config;
    let image = mllm.manifest.synthetic_image();
    let prompt = mllm.manifest.parity.prompt.clone();
    let budget = cfg.max_len - cfg.prefill_len;
    let gen = mllm.generate(&image, &prompt, budget + 50).unwrap();
    assert!(gen.tokens.len() <= budget + 1, "generated past KV capacity");
}
