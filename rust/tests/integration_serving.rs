//! Integration tests: the L3 serving coordinator end to end — admission,
//! batching, pipelining, sharding, metrics — over both backends.

use chime::config::{ChimeConfig, MllmConfig};
use chime::coordinator::{
    BatchPolicy, FunctionalServer, RoutePolicy, ServeRequest, ShardedServer, SimulatedServer,
};
use chime::model::workload::RequestStream;
use chime::runtime::Manifest;

fn stream_requests(n: usize, rate: f64, tokens: usize, vocab: usize) -> Vec<ServeRequest> {
    let mut s = RequestStream::new(3, rate, 16, tokens, vocab);
    s.take(n)
        .into_iter()
        .map(|r| ServeRequest {
            id: r.id,
            prompt: r.prompt,
            image_seed: r.image_seed,
            max_new_tokens: r.max_new_tokens,
            arrival_ns: r.arrival_ns,
        })
        .collect()
}

#[test]
fn simulated_serving_conserves_requests_and_tokens() {
    let mut cfg = ChimeConfig::default();
    cfg.workload.output_tokens = 8;
    let mut srv = SimulatedServer::new(&MllmConfig::fastvlm_0_6b(), &cfg, BatchPolicy::default());
    let reqs = stream_requests(10, 5.0, 8, 256);
    let out = srv.serve(reqs);
    assert_eq!(out.responses.len(), 10);
    assert!(out.shed.is_empty());
    assert_eq!(out.metrics.completed, 10);
    assert_eq!(out.metrics.admitted, 10);
    assert_eq!(out.metrics.rejected, 0);
    assert_eq!(out.metrics.tokens, 80);
    // Every response accounted and causally ordered.
    for r in &out.responses {
        assert!(r.queue_ns >= 0.0);
        assert!(r.ttft_ns > 0.0);
        assert!(r.service_ns >= r.ttft_ns);
        assert!(r.energy_j > 0.0);
    }
}

#[test]
fn higher_arrival_rate_increases_queueing() {
    let mut cfg = ChimeConfig::default();
    cfg.workload.output_tokens = 16;
    let policy = BatchPolicy { max_batch: 2, ..BatchPolicy::default() };
    let slow = {
        let mut srv = SimulatedServer::new(&MllmConfig::mobilevlm_1_7b(), &cfg, policy.clone());
        let mut m = srv.serve(stream_requests(12, 0.5, 16, 32000)).metrics;
        m.latency_percentile_ns(90.0)
    };
    let fast = {
        let mut srv = SimulatedServer::new(&MllmConfig::mobilevlm_1_7b(), &cfg, policy);
        let mut m = srv.serve(stream_requests(12, 100.0, 16, 32000)).metrics;
        m.latency_percentile_ns(90.0)
    };
    assert!(
        fast > slow,
        "saturating arrivals must queue: p90 {fast} vs {slow}"
    );
}

#[test]
fn pipelined_batching_beats_serial_ticks() {
    // The two-cut-point flow-shop must strictly beat serialized execution
    // for multi-request ticks (paper's "without idle cycles" claim, made
    // measurable).
    use chime::coordinator::pipeline::{schedule_tick, StepWork};
    let jobs: Vec<StepWork> = (0..4)
        .map(|i| StepWork { id: i, dram_ns: 1.0e6, rram_ns: 1.2e6 })
        .collect();
    let (_, pipelined, serial) = schedule_tick(&jobs);
    assert!(pipelined < serial * 0.72, "pipelined {pipelined} serial {serial}");
}

#[test]
fn two_packages_beat_one_on_a_saturating_burst() {
    // Acceptance gate: a 2-package deployment must deliver >= 1.5x system
    // tokens/s on a burst that saturates one package.
    let mut cfg = ChimeConfig::default();
    cfg.workload.output_tokens = 32;
    let model = MllmConfig::fastvlm_0_6b();
    let burst = || ServeRequest::burst(16, 32);
    let run = |packages: usize| {
        let mut srv = ShardedServer::new(
            &model,
            &cfg,
            BatchPolicy::default(),
            packages,
            RoutePolicy::RoundRobin,
        );
        let out = srv.serve(burst());
        assert_eq!(out.responses.len(), 16, "{packages} packages must drain the burst");
        assert!(out.shed.is_empty());
        assert_eq!(out.metrics.tokens, 16 * 32);
        out.metrics.tokens_per_s()
    };
    let one = run(1);
    let two = run(2);
    assert!(
        two >= one * 1.5,
        "2 packages {two:.1} tok/s vs 1 package {one:.1} tok/s (< 1.5x)"
    );
}

#[test]
fn sharded_serving_handles_poisson_arrivals_across_policies() {
    // The sharded path must preserve the per-request causality contract of
    // the single-package engine under both routing policies.
    let mut cfg = ChimeConfig::default();
    cfg.workload.output_tokens = 8;
    for route in [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded] {
        let mut srv =
            ShardedServer::new(&MllmConfig::fastvlm_0_6b(), &cfg, BatchPolicy::default(), 3, route);
        let out = srv.serve(stream_requests(12, 20.0, 8, 256));
        assert_eq!(out.responses.len(), 12, "{} lost requests", route.name());
        assert_eq!(out.metrics.completed + out.metrics.rejected, 12);
        for r in &out.responses {
            assert!(r.queue_ns >= 0.0);
            assert!(r.ttft_ns > 0.0);
            assert!(r.service_ns >= r.ttft_ns);
        }
        // All three packages saw work under a 12-request spread.
        let completed = srv.package_completed();
        assert_eq!(completed.iter().sum::<u64>(), 12);
    }
}

#[test]
fn functional_serving_end_to_end() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut srv = FunctionalServer::load(&dir).unwrap();
    let meta_prompt_len = srv.mllm.manifest.config.prompt_len;
    let vocab = srv.mllm.manifest.config.vocab;
    let mut s = RequestStream::new(9, 10.0, meta_prompt_len, 5, vocab);
    let reqs: Vec<ServeRequest> = s
        .take(4)
        .into_iter()
        .map(|r| ServeRequest {
            id: r.id,
            prompt: r.prompt,
            image_seed: r.image_seed,
            max_new_tokens: r.max_new_tokens,
            arrival_ns: 0.0,
        })
        .collect();
    let (resps, metrics) = srv.serve(&reqs).unwrap();
    assert_eq!(resps.len(), 4);
    assert_eq!(metrics.tokens, 20);
    // One-timebase queueing (timebase-mixing regression): simultaneous
    // arrivals on a sequential stream queue behind exactly their
    // predecessors' measured service time — not behind a wall-minus-
    // virtual difference.
    let mut backlog = 0.0;
    for r in &resps {
        assert!(
            (r.queue_ns - backlog).abs() <= backlog * 1e-9 + 1e-6,
            "req {}: queue {} != predecessor backlog {}",
            r.id,
            r.queue_ns,
            backlog
        );
        backlog += r.service_ns;
    }
    for r in &resps {
        assert_eq!(r.tokens.len(), 5);
        assert!(r.tokens.iter().all(|&t| (0..vocab as i32).contains(&t)));
        assert!(r.service_ns > 0.0);
        assert!(r.energy_j > 0.0, "simulated CHIME energy attached");
    }
    // Same seed -> same tokens (determinism through the whole stack).
    let (resps2, _) = srv.serve(&reqs).unwrap();
    for (a, b) in resps.iter().zip(&resps2) {
        assert_eq!(a.tokens, b.tokens);
    }
}
