//! Property-based tests over coordinator/mapping/substrate invariants.
//!
//! proptest is not vendored in this offline environment, so this file
//! carries a small seeded-random property harness (`check`) on top of
//! `chime::util::Prng`: N random cases per property, failures reported
//! with the case index + seed for reproduction.

use chime::config::{ChimeHardware, LlmConfig, MllmConfig};
use chime::coordinator::pipeline::{johnson_order, makespan, serial_time, StepWork};
use chime::mapping::{fusion, layout};
use chime::model::backbone;
use chime::sim::memory::dram::WeightClass;
use chime::sim::memory::DramState;
use chime::util::{Json, Prng};

const CASES: usize = 200;

/// Tiny property harness: run `prop` on CASES seeded cases.
fn check(name: &str, mut prop: impl FnMut(&mut Prng) -> Result<(), String>) {
    for case in 0..CASES {
        let seed = 0xC41_3E55 ^ (case as u64);
        let mut prng = Prng::new(seed);
        if let Err(msg) = prop(&mut prng) {
            panic!("property {name} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

fn random_llm(prng: &mut Prng) -> LlmConfig {
    let d_head = *prng.choice(&[16usize, 32, 64, 128]);
    let n_heads = prng.range(1, 33);
    let n_kv_heads = 1 + prng.range(0, n_heads);
    LlmConfig {
        d_model: d_head * prng.range(1, 40),
        n_layers: prng.range(1, 48),
        n_heads,
        n_kv_heads,
        d_head,
        d_ffn: prng.range(64, 20_000),
        ffn_matrices: *prng.choice(&[2usize, 3]),
        vocab: prng.range(256, 200_000),
        tied_embeddings: prng.bool(),
        bytes_per_param: 2,
    }
}

#[test]
fn prop_fusion_never_splits_chiplets_and_keeps_two_cut_points() {
    check("fusion invariants", |prng| {
        let llm = random_llm(prng);
        let pos = prng.range(1, 4096);
        let ops = backbone::decode_ops(&llm, pos);
        let kernels = fusion::fuse_ops(&ops, 1);
        fusion::validate(&kernels).map_err(|e| e)?;
        let cut_outs = kernels.iter().filter(|k| k.cut_out).count();
        if cut_outs != 2 * llm.n_layers {
            return Err(format!(
                "expected {} cut points, got {cut_outs}",
                2 * llm.n_layers
            ));
        }
        // Conservation: fused kernels carry exactly the ops' totals.
        let op_w: u64 = ops.iter().map(|o| o.weight_bytes).sum();
        let k_w: u64 = kernels.iter().map(|k| k.weight_bytes()).sum();
        if op_w != k_w {
            return Err(format!("weight bytes {op_w} != fused {k_w}"));
        }
        let op_f: f64 = ops.iter().map(|o| o.flops).sum();
        let k_f: f64 = kernels.iter().map(|k| k.flops()).sum();
        if (op_f - k_f).abs() > 1.0 {
            return Err(format!("flops {op_f} != fused {k_f}"));
        }
        Ok(())
    });
}

#[test]
fn prop_decode_weight_traffic_independent_of_position() {
    // Weights stream once per step regardless of context length; only KV
    // traffic grows.
    check("weights independent of pos", |prng| {
        let llm = random_llm(prng);
        let p1 = prng.range(1, 2000);
        let p2 = p1 + prng.range(1, 2000);
        let w = |pos: usize| -> u64 {
            backbone::decode_ops(&llm, pos).iter().map(|o| o.weight_bytes).sum()
        };
        if w(p1) != w(p2) {
            return Err(format!("weight bytes differ: {} vs {}", w(p1), w(p2)));
        }
        let kv = |pos: usize| -> u64 {
            backbone::decode_ops(&llm, pos).iter().map(|o| o.kv_read_bytes).sum()
        };
        if kv(p2) <= kv(p1) {
            return Err("kv traffic must grow with position".into());
        }
        Ok(())
    });
}

#[test]
fn prop_dram_tier_allocator_conserves_bytes() {
    check("dram allocator conservation", |prng| {
        let mut cfg = chime::config::DramConfig::default();
        cfg.tier_capacity_bytes = prng.range(1_000, 1_000_000) as u64;
        let cap = cfg.tier_capacity_bytes * cfg.tiers as u64;
        let mut dram = DramState::new(cfg);
        let weights = (prng.f64() * cap as f64 * 0.9) as u64;
        dram.place_weights_classed(WeightClass::Attn, weights).map_err(|o| format!("overflow {o}"))?;
        let mut appended = 0u64;
        let mut offloaded = 0u64;
        for _ in 0..prng.range(1, 30) {
            let chunk = prng.range(1, 200_000) as u64;
            appended += chunk;
            offloaded += dram.append_kv(chunk);
        }
        // Conservation: every appended byte is in a tier or offloaded.
        let resident: u64 = dram.tiers.iter().map(|t| t.kv).sum();
        if resident + offloaded != appended {
            return Err(format!(
                "lost bytes: resident {resident} + offloaded {offloaded} != appended {appended}"
            ));
        }
        // Capacity: no tier overfilled.
        for (i, t) in dram.tiers.iter().enumerate() {
            if t.weights + t.kv > t.capacity {
                return Err(format!("tier {i} overfilled"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_johnson_rule_never_worse_than_fifo_or_reverse() {
    check("johnson optimality vs heuristics", |prng| {
        let n = prng.range(1, 12);
        let jobs: Vec<StepWork> = (0..n)
            .map(|id| StepWork {
                id,
                dram_ns: prng.uniform(1.0, 1000.0),
                rram_ns: prng.uniform(1.0, 1000.0),
            })
            .collect();
        let jspan = makespan(&johnson_order(&jobs));
        let fifo = makespan(&jobs);
        let mut rev = jobs.clone();
        rev.reverse();
        let rspan = makespan(&rev);
        if jspan > fifo + 1e-9 || jspan > rspan + 1e-9 {
            return Err(format!("johnson {jspan} worse than fifo {fifo} / reverse {rspan}"));
        }
        // Makespan bounds: max(total_dram + min_rram_tail, ...) <= span <= serial.
        let serial = serial_time(&jobs);
        let dram_total: f64 = jobs.iter().map(|x| x.dram_ns).sum();
        let rram_total: f64 = jobs.iter().map(|x| x.rram_ns).sum();
        let lower = dram_total.max(rram_total);
        if jspan < lower - 1e-9 || jspan > serial + 1e-9 {
            return Err(format!("span {jspan} outside [{lower}, {serial}]"));
        }
        Ok(())
    });
}

#[test]
fn prop_weight_layout_partitions_model_bytes() {
    check("layout partitions bytes", |prng| {
        let hw = ChimeHardware::default();
        let mut model = MllmConfig::paper_models()[prng.range(0, 4)].clone();
        // Jitter dimensions to explore the space (kept placeable).
        model.llm.n_layers = prng.range(1, 40);
        model.llm.d_ffn = prng.range(64, 12_000);
        let l = layout::WeightLayout::plan(&model, &hw);
        let class_sum: u64 = l.dram_classes.iter().map(|(_, b)| b).sum();
        if class_sum != l.dram_weight_bytes {
            return Err(format!(
                "classes {class_sum} != dram total {}",
                l.dram_weight_bytes
            ));
        }
        if l.rram_weight_bytes > hw.rram.chip_capacity_bytes {
            return Err("rram overfilled".into());
        }
        if l.dram_weight_bytes > hw.dram.chip_capacity_bytes() {
            return Err("dram overfilled".into());
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    check("json roundtrip", |prng| {
        let v = random_json(prng, 0);
        let text = v.pretty();
        let back = Json::parse(&text).map_err(|e| e.to_string())?;
        if back != v {
            return Err(format!("roundtrip mismatch:\n{text}"));
        }
        let compact = Json::parse(&v.compact()).map_err(|e| e.to_string())?;
        if compact != v {
            return Err("compact roundtrip mismatch".into());
        }
        Ok(())
    });
}

fn random_json(prng: &mut Prng, depth: usize) -> Json {
    let pick = if depth > 3 { prng.range(0, 4) } else { prng.range(0, 6) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(prng.bool()),
        2 => {
            // Round to avoid float-text precision mismatches.
            let v = (prng.uniform(-1e6, 1e6) * 1000.0).round() / 1000.0;
            Json::Num(v)
        }
        3 => {
            let len = prng.range(0, 12);
            let s: String = (0..len)
                .map(|_| {
                    let c = prng.range(32, 127) as u8 as char;
                    c
                })
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr((0..prng.range(0, 5)).map(|_| random_json(prng, depth + 1)).collect()),
        _ => {
            let mut obj = std::collections::BTreeMap::new();
            for i in 0..prng.range(0, 5) {
                obj.insert(format!("k{i}"), random_json(prng, depth + 1));
            }
            Json::Obj(obj)
        }
    }
}

#[test]
fn prop_dram_tier_placement_never_exceeds_capacity() {
    // ❶ layout mechanics: classed weight placement may only fill a tier to
    // `TierState::capacity`, `free()` must never underflow (it must equal
    // capacity - weights - kv exactly once occupancy is legal), and
    // placement must conserve bytes (placed + reported overflow == asked).
    check("tier placement capacity", |prng| {
        let mut cfg = chime::config::DramConfig::default();
        cfg.tier_capacity_bytes = prng.range(1_000, 500_000) as u64;
        let cap = cfg.tier_capacity_bytes * cfg.tiers as u64;
        let mut dram = DramState::new(cfg);
        let classes = WeightClass::all_in_priority_order();
        let mut asked = 0u64;
        let mut overflowed = 0u64;
        for _ in 0..prng.range(1, 12) {
            let class = *prng.choice(&classes);
            let bytes = prng.range(0, (cap / 2) as usize + 1) as u64;
            asked += bytes;
            if let Err(over) = dram.place_weights_classed(class, bytes) {
                if over > bytes {
                    return Err(format!("overflow {over} exceeds request {bytes}"));
                }
                overflowed += over;
            }
            for (i, t) in dram.tiers.iter().enumerate() {
                if t.weights + t.kv > t.capacity {
                    return Err(format!(
                        "tier {i} overfilled: {} + {} > {}",
                        t.weights, t.kv, t.capacity
                    ));
                }
                if t.free() != t.capacity - t.weights - t.kv {
                    return Err(format!("tier {i} free() inconsistent"));
                }
            }
        }
        let placed: u64 = dram.tiers.iter().map(|t| t.weights).sum();
        if placed + overflowed != asked {
            return Err(format!(
                "bytes lost: placed {placed} + overflow {overflowed} != asked {asked}"
            ));
        }
        if placed > cap {
            return Err(format!("placed {placed} exceeds stack capacity {cap}"));
        }
        Ok(())
    });
}

#[test]
fn prop_kv_offload_one_shot_monotone() {
    // ❷ endurance-aware offload: the DRAM-side offload ledger only ever
    // grows (write-once — offloaded blocks never silently return), each
    // append's return value matches the ledger delta, and the RRAM
    // endurance/write counters are monotone under the offload stream.
    check("kv offload one-shot monotonicity", |prng| {
        let mut cfg = chime::config::DramConfig::default();
        cfg.tier_capacity_bytes = prng.range(1_000, 200_000) as u64;
        let cap = cfg.tier_capacity_bytes * cfg.tiers as u64;
        let mut dram = DramState::new(cfg);
        let mut rram = chime::sim::memory::RramState::new(chime::config::RramConfig::default());
        // Random static weight load (may fill most of the stack).
        let weights = (prng.f64() * cap as f64) as u64;
        let _ = dram.place_weights(weights);
        let mut last_offloaded = 0u64;
        let mut last_endurance = 0.0f64;
        let mut last_writes = 0u64;
        for _ in 0..prng.range(1, 40) {
            let chunk = prng.range(1, 100_000) as u64;
            let before = dram.kv_offloaded;
            let off = dram.append_kv(chunk);
            if dram.kv_offloaded != before + off {
                return Err(format!(
                    "offload ledger delta {} != returned {off}",
                    dram.kv_offloaded - before
                ));
            }
            if dram.kv_offloaded < last_offloaded {
                return Err("kv_offloaded decreased (write-once violated)".into());
            }
            last_offloaded = dram.kv_offloaded;
            if off > 0 {
                rram.offload_kv(off);
                if rram.endurance_consumed() < last_endurance {
                    return Err("rram endurance went backwards".into());
                }
                if rram.lifetime_write_bytes < last_writes {
                    return Err("rram lifetime writes went backwards".into());
                }
                last_endurance = rram.endurance_consumed();
                last_writes = rram.lifetime_write_bytes;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_johnson_makespan_bounded_by_serial() {
    // Two-machine flow shop: johnson_order must be a permutation of the
    // jobs, and its makespan must sit in [max(ΣD, ΣR), serial_time].
    check("johnson permutation and serial bound", |prng| {
        let n = prng.range(1, 16);
        let jobs: Vec<StepWork> = (0..n)
            .map(|id| StepWork {
                id,
                dram_ns: prng.uniform(1.0, 1e6),
                rram_ns: prng.uniform(1.0, 1e6),
            })
            .collect();
        let order = johnson_order(&jobs);
        if order.len() != jobs.len() {
            return Err(format!("order has {} jobs, expected {}", order.len(), jobs.len()));
        }
        let mut ids: Vec<usize> = order.iter().map(|j| j.id).collect();
        ids.sort_unstable();
        if ids != (0..n).collect::<Vec<_>>() {
            return Err("johnson_order is not a permutation of the input".into());
        }
        let span = makespan(&order);
        let serial = serial_time(&jobs);
        if span > serial + 1e-6 {
            return Err(format!("makespan {span} exceeds serial time {serial}"));
        }
        let dram_total: f64 = jobs.iter().map(|x| x.dram_ns).sum();
        let rram_total: f64 = jobs.iter().map(|x| x.rram_ns).sum();
        if span + 1e-6 < dram_total.max(rram_total) {
            return Err(format!(
                "makespan {span} below machine lower bound {}",
                dram_total.max(rram_total)
            ));
        }
        if n == 1 && (span - serial).abs() > 1e-9 {
            return Err("single job cannot pipeline".into());
        }
        Ok(())
    });
}

#[test]
fn prop_johnson_optimal_under_ties_and_adversarial_costs() {
    // Satellite of the NaN/tie fix: Johnson's rule must remain a
    // permutation and optimal (vs exhaustive search) when costs are drawn
    // from an adversarial pool — exact ties (dram == rram), zeros,
    // near-epsilon values, and 12-orders-of-magnitude mixes.
    check("johnson ties + adversarial distributions", |prng| {
        let pool = [0.0, 1.0, 1.0, 2.5, 1e-9, 1e3, 1e12];
        let n = prng.range(1, 6); // 5! = 120 permutations max
        let jobs: Vec<StepWork> = (0..n)
            .map(|id| {
                let d = *prng.choice(&pool);
                // Half the jobs get an exact tie on the two machines.
                let r = if prng.bool() { d } else { *prng.choice(&pool) };
                StepWork::new(id, d, r)
            })
            .collect();
        let order = johnson_order(&jobs);
        let mut ids: Vec<usize> = order.iter().map(|j| j.id).collect();
        ids.sort_unstable();
        if ids != (0..n).collect::<Vec<_>>() {
            return Err("johnson_order is not a permutation".into());
        }
        let jspan = makespan(&order);
        // Exhaustive optimum over all n! orders.
        let mut best = f64::INFINITY;
        let mut idx: Vec<usize> = (0..n).collect();
        heap_permute(&mut idx, &mut |perm: &[usize]| {
            let o: Vec<StepWork> = perm.iter().map(|&i| jobs[i]).collect();
            best = best.min(makespan(&o));
        });
        // Relative tolerance: the pool spans 12 orders of magnitude.
        if jspan > best * (1.0 + 1e-12) + 1e-9 {
            return Err(format!("johnson {jspan} worse than optimal {best}"));
        }
        Ok(())
    });
}

/// Heap's algorithm permutation helper (shared by the Johnson properties).
fn heap_permute(idx: &mut Vec<usize>, f: &mut impl FnMut(&[usize])) {
    fn heap(k: usize, a: &mut Vec<usize>, f: &mut impl FnMut(&[usize])) {
        if k <= 1 {
            f(a);
            return;
        }
        for i in 0..k {
            heap(k - 1, a, f);
            if k % 2 == 0 {
                a.swap(i, k - 1);
            } else {
                a.swap(0, k - 1);
            }
        }
    }
    let n = idx.len();
    heap(n, idx, f);
}

#[test]
fn prop_sharded_serving_conserves_and_orders() {
    // Tentpole invariants, per random case: (1) conservation — every
    // offered request either completes or is returned shed, with matching
    // admitted/rejected counters; (2) per-request causality — queue >= 0,
    // ttft <= service; (3) the event-ordered merge returns responses in
    // global completion order.
    use chime::config::{ChimeConfig, WorkloadConfig};
    use chime::coordinator::{BatchPolicy, RoutePolicy, ServeRequest, ShardedServer};

    let model = MllmConfig::tiny();
    let mut cfg = ChimeConfig::default();
    cfg.workload = WorkloadConfig { image_size: 64, text_tokens: 8, output_tokens: 4 };

    check("sharded conservation + completion order", |prng| {
        let packages = prng.range(1, 4);
        let route = if prng.bool() { RoutePolicy::RoundRobin } else { RoutePolicy::LeastLoaded };
        let policy = BatchPolicy {
            max_batch: prng.range(1, 4),
            queue_capacity: prng.range(1, 8),
        };
        let n = prng.range(1, 10);
        let requests: Vec<ServeRequest> = (0..n)
            .map(|i| ServeRequest {
                id: i as u64,
                prompt: vec![],
                image_seed: i as u64,
                // Include zero-token requests (immediate completion path).
                max_new_tokens: prng.range(0, 6),
                arrival_ns: prng.uniform(0.0, 5e8),
            })
            .collect();
        let mut srv = ShardedServer::new(&model, &cfg, policy, packages, route);
        let out = srv.serve(requests.clone());

        // (1) conservation
        if out.responses.len() + out.shed.len() != n {
            return Err(format!(
                "lost requests: {} completed + {} shed != {n}",
                out.responses.len(),
                out.shed.len()
            ));
        }
        if out.metrics.completed != out.responses.len() as u64
            || out.metrics.rejected != out.shed.len() as u64
            || out.metrics.admitted != out.metrics.completed
            || out.metrics.offered() != n as u64
        {
            return Err(format!(
                "counters drifted: completed {} rejected {} admitted {} offered {}",
                out.metrics.completed,
                out.metrics.rejected,
                out.metrics.admitted,
                out.metrics.offered()
            ));
        }
        let mut ids: Vec<u64> = out
            .responses
            .iter()
            .map(|r| r.id)
            .chain(out.shed.iter().map(|r| r.id))
            .collect();
        ids.sort_unstable();
        if ids != (0..n as u64).collect::<Vec<_>>() {
            return Err("request identities not conserved".into());
        }

        // (2) per-request causality + token accounting
        for r in &out.responses {
            let req = &requests[r.id as usize];
            if r.tokens.len() != req.max_new_tokens {
                return Err(format!(
                    "req {} produced {} tokens, asked {}",
                    r.id,
                    r.tokens.len(),
                    req.max_new_tokens
                ));
            }
            if r.queue_ns < 0.0 || r.ttft_ns < 0.0 || r.service_ns < r.ttft_ns {
                return Err(format!(
                    "req {}: causality violated (queue {}, ttft {}, service {})",
                    r.id, r.queue_ns, r.ttft_ns, r.service_ns
                ));
            }
        }

        // (3) completion order of the event merge
        let finish: Vec<f64> = out
            .responses
            .iter()
            .map(|r| requests[r.id as usize].arrival_ns + r.total_latency_ns())
            .collect();
        for w in finish.windows(2) {
            if w[0] > w[1] {
                return Err(format!("merge out of completion order: {finish:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_streaming_serve_is_conserving_causal_and_steal_token_safe() {
    // Streaming-protocol invariants, per random case and for BOTH steal
    // modes: (1) event-count conservation — every submitted request gets
    // exactly one admission decision (Admitted xor Rejected xor Shed),
    // admitted requests complete exactly once, and Token events number
    // exactly max_new_tokens; (2) causal order — no Token before
    // FirstToken, sequential token indices, no event before the request's
    // arrival; (3) `--steal on` never changes the total tokens emitted
    // (it relocates queued work, it does not re-price or re-count it).
    use chime::config::{ChimeConfig, WorkloadConfig};
    use chime::coordinator::{
        BatchPolicy, RoutePolicy, ServeEvent, ServeRequest, ShardedServer,
    };
    use std::collections::BTreeMap;

    let model = MllmConfig::tiny();
    let mut cfg = ChimeConfig::default();
    cfg.workload = WorkloadConfig { image_size: 64, text_tokens: 8, output_tokens: 4 };

    #[derive(Default)]
    struct Lifecycle {
        admitted: u32,
        rejected: u32,
        shed: u32,
        first: u32,
        tokens: u32,
        completed: u32,
    }

    check("streaming conservation + causality + steal token-safety", |prng| {
        let packages = prng.range(1, 4);
        let route = if prng.bool() { RoutePolicy::RoundRobin } else { RoutePolicy::LeastLoaded };
        let max_batch = prng.range(1, 4);
        let n = prng.range(1, 10);
        let requests: Vec<ServeRequest> = (0..n)
            .map(|i| ServeRequest {
                id: i as u64,
                prompt: vec![],
                image_seed: i as u64,
                max_new_tokens: prng.range(0, 6),
                // Occasionally unschedulable (exercises the Shed path).
                arrival_ns: if prng.range(0, 12) == 0 {
                    f64::NAN
                } else {
                    prng.uniform(0.0, 5e8)
                },
            })
            .collect();

        let run = |policy: &BatchPolicy, steal: bool| -> (Vec<ServeEvent>, usize, usize, u64) {
            let mut srv = ShardedServer::new(&model, &cfg, policy.clone(), packages, route);
            srv.set_work_stealing(steal);
            let mut session = srv.open_serving();
            let mut events = Vec::new();
            for r in requests.clone() {
                events.extend(session.submit(r));
            }
            events.extend(session.drain());
            let out = session.finish();
            (events, out.responses.len(), out.shed.len(), out.metrics.tokens)
        };

        // Token safety is compared without admission backpressure: with
        // tight queues, stealing legitimately shifts queue occupancy over
        // time and with it which requests clear admission, so emitted
        // tokens are only comparable when nothing can be rejected.
        let ample = BatchPolicy { max_batch, queue_capacity: n.max(1) };
        let (events_off, done_off, shed_off, tokens_off) = run(&ample, false);
        let (events_on, done_on, shed_on, tokens_on) = run(&ample, true);
        // (3) steal token-safety (equality, which implies "never more").
        if tokens_on != tokens_off {
            return Err(format!("steal changed tokens: {tokens_on} vs {tokens_off}"));
        }
        if done_on != done_off || shed_on != shed_off {
            return Err("steal changed admission outcomes without backpressure".into());
        }
        if done_on + shed_on != n || done_off + shed_off != n {
            return Err("outcome lost requests".into());
        }

        // A separate tight-queue run exercises the Rejected path; its
        // event stream must satisfy the same lifecycle contract.
        let tight = BatchPolicy { max_batch, queue_capacity: prng.range(1, 4) };
        let steal_tight = prng.bool();
        let (events_tight, done_tight, shed_tight, _) = run(&tight, steal_tight);
        if done_tight + shed_tight != n {
            return Err("tight-queue outcome lost requests".into());
        }

        for (mode, events) in
            [("off", &events_off), ("on", &events_on), ("tight", &events_tight)]
        {
            let mut per: BTreeMap<u64, Lifecycle> = BTreeMap::new();
            for ev in events.iter() {
                let id = ev.id();
                let arrival = requests[id as usize].arrival_ns;
                if let Some(t) = ev.time_ns() {
                    if arrival.is_finite() && t < arrival {
                        return Err(format!("{mode}: req {id} event at {t} before arrival"));
                    }
                }
                let st = per.entry(id).or_default();
                match ev {
                    ServeEvent::Admitted { .. } => st.admitted += 1,
                    ServeEvent::Rejected { .. } => st.rejected += 1,
                    ServeEvent::Shed { .. } => st.shed += 1,
                    ServeEvent::FirstToken { .. } => {
                        if st.admitted != 1 {
                            return Err(format!("{mode}: req {id} first-token before admission"));
                        }
                        st.first += 1;
                    }
                    ServeEvent::Token { index, .. } => {
                        if st.first != 1 {
                            return Err(format!("{mode}: req {id} token before first-token"));
                        }
                        if *index != st.tokens as usize {
                            return Err(format!(
                                "{mode}: req {id} token index {index}, expected {}",
                                st.tokens
                            ));
                        }
                        st.tokens += 1;
                    }
                    ServeEvent::Completed { .. } => {
                        if st.admitted != 1 {
                            return Err(format!("{mode}: req {id} completed without admission"));
                        }
                        st.completed += 1;
                    }
                    ServeEvent::Stolen { from, to, .. } => {
                        if from == to {
                            return Err(format!("{mode}: req {id} stolen onto its own package"));
                        }
                        if st.admitted != 1 {
                            return Err(format!("{mode}: req {id} stolen before admission"));
                        }
                    }
                }
            }
            // Event-count conservation over the whole stream.
            let decisions: u32 = per.values().map(|s| s.admitted + s.rejected + s.shed).sum();
            if decisions != n as u32 {
                return Err(format!("{mode}: {decisions} admission decisions for {n} requests"));
            }
            for (id, st) in &per {
                if st.admitted + st.rejected + st.shed != 1 {
                    return Err(format!("{mode}: req {id} has multiple admission decisions"));
                }
                if st.completed != st.admitted {
                    return Err(format!("{mode}: req {id} admitted but not completed"));
                }
                if st.admitted == 1 {
                    let budget = requests[*id as usize].max_new_tokens as u32;
                    if st.tokens != budget {
                        return Err(format!(
                            "{mode}: req {id} emitted {} tokens, budget {budget}",
                            st.tokens
                        ));
                    }
                    let expect_first = u32::from(budget > 0);
                    if st.first != expect_first {
                        return Err(format!("{mode}: req {id} first-token count {}", st.first));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_drain_is_bit_identical_to_sequential() {
    // The parallel per-package drain (`ShardedServer::set_parallel`) must
    // be invisible: over random package counts, routes, batch policies,
    // arrival streams (including NaN arrivals and tight queues), and both
    // steal modes, the full `ServeOutcome` — every response float, the
    // shed list, and every order-dependent metric accumulation — must
    // serialize to byte-identical canonical JSON against the sequential
    // path.
    use chime::config::{ChimeConfig, WorkloadConfig};
    use chime::coordinator::{BatchPolicy, RoutePolicy, ServeOutcome, ServeRequest, ShardedServer};

    let model = MllmConfig::tiny();
    let mut cfg = ChimeConfig::default();
    cfg.workload = WorkloadConfig { image_size: 64, text_tokens: 8, output_tokens: 4 };

    fn outcome_json(out: &ServeOutcome) -> String {
        let rows: Vec<Json> = out
            .responses
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("id", (r.id as i64).into()),
                    ("tokens", r.tokens.len().into()),
                    ("queue_ns", r.queue_ns.into()),
                    ("ttft_ns", r.ttft_ns.into()),
                    ("service_ns", r.service_ns.into()),
                    ("energy_j", r.energy_j.into()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("responses", Json::Arr(rows)),
            ("shed", Json::arr(out.shed.iter().map(|r| Json::from(r.id as i64)))),
            ("completed", (out.metrics.completed as i64).into()),
            ("rejected", (out.metrics.rejected as i64).into()),
            ("shed_count", (out.metrics.shed as i64).into()),
            ("tokens", (out.metrics.tokens as i64).into()),
            ("steals", (out.metrics.steals as i64).into()),
            ("stolen_bytes", (out.metrics.stolen_bytes as i64).into()),
            // Order-dependent float accumulations: these move if the
            // completion stream is replayed in any other order.
            ("steal_delay_ns", out.metrics.steal_delay_ns.into()),
            ("energy_j", out.metrics.energy_j.into()),
            ("span_ns", out.metrics.span_ns().into()),
            ("service_stddev", out.metrics.service.stddev().into()),
            ("tokens_per_s", out.metrics.tokens_per_s().into()),
        ])
        .pretty()
    }

    check("parallel drain bit-identity", |prng| {
        let packages = prng.range(1, 5);
        let route = if prng.bool() { RoutePolicy::RoundRobin } else { RoutePolicy::LeastLoaded };
        let steal = prng.bool();
        let policy = BatchPolicy {
            max_batch: prng.range(1, 4),
            queue_capacity: prng.range(1, 10),
        };
        let n = prng.range(1, 12);
        let requests: Vec<ServeRequest> = (0..n)
            .map(|i| ServeRequest {
                id: i as u64,
                prompt: vec![],
                image_seed: i as u64,
                max_new_tokens: prng.range(0, 8),
                arrival_ns: if prng.range(0, 12) == 0 {
                    f64::NAN
                } else {
                    prng.uniform(0.0, 5e8)
                },
            })
            .collect();
        let run = |parallel: bool| -> String {
            let mut srv = ShardedServer::new(&model, &cfg, policy.clone(), packages, route);
            srv.set_work_stealing(steal);
            srv.set_parallel(parallel);
            outcome_json(&srv.serve(requests.clone()))
        };
        let (seq, par) = (run(false), run(true));
        if seq != par {
            return Err(format!(
                "parallel drain diverged (packages {packages}, steal {steal}):\n\
                 sequential:\n{seq}\nparallel:\n{par}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_exec_drain_is_bit_identical_to_sequential() {
    // The windowed executor drain (`ShardedServer::set_threads` > 1,
    // DESIGN.md §15) carries the same invisibility contract as the
    // parallel per-package drain above: over random package counts,
    // routes, batch policies, thread counts, arrival streams (NaN
    // arrivals, tight queues, zero-token requests), and both steal modes
    // (steal on falls back to the sequential event loop — the gate must
    // be exact), the full `ServeOutcome` serializes to byte-identical
    // canonical JSON against the single-thread path.
    use chime::config::{ChimeConfig, WorkloadConfig};
    use chime::coordinator::{BatchPolicy, RoutePolicy, ServeOutcome, ServeRequest, ShardedServer};

    let model = MllmConfig::tiny();
    let mut cfg = ChimeConfig::default();
    cfg.workload = WorkloadConfig { image_size: 64, text_tokens: 8, output_tokens: 4 };

    fn outcome_json(out: &ServeOutcome) -> String {
        let rows: Vec<Json> = out
            .responses
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("id", (r.id as i64).into()),
                    ("tokens", r.tokens.len().into()),
                    ("queue_ns", r.queue_ns.into()),
                    ("ttft_ns", r.ttft_ns.into()),
                    ("service_ns", r.service_ns.into()),
                    ("energy_j", r.energy_j.into()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("responses", Json::Arr(rows)),
            ("shed", Json::arr(out.shed.iter().map(|r| Json::from(r.id as i64)))),
            ("completed", (out.metrics.completed as i64).into()),
            ("rejected", (out.metrics.rejected as i64).into()),
            ("shed_count", (out.metrics.shed as i64).into()),
            ("tokens", (out.metrics.tokens as i64).into()),
            ("steals", (out.metrics.steals as i64).into()),
            ("stolen_bytes", (out.metrics.stolen_bytes as i64).into()),
            ("steal_delay_ns", out.metrics.steal_delay_ns.into()),
            ("energy_j", out.metrics.energy_j.into()),
            ("span_ns", out.metrics.span_ns().into()),
            ("service_stddev", out.metrics.service.stddev().into()),
            ("tokens_per_s", out.metrics.tokens_per_s().into()),
        ])
        .pretty()
    }

    check("executor drain bit-identity", |prng| {
        let packages = prng.range(1, 5);
        let route = if prng.bool() { RoutePolicy::RoundRobin } else { RoutePolicy::LeastLoaded };
        let steal = prng.bool();
        let threads = prng.range(2, 9);
        let policy = BatchPolicy {
            max_batch: prng.range(1, 4),
            queue_capacity: prng.range(1, 10),
        };
        let n = prng.range(1, 12);
        let requests: Vec<ServeRequest> = (0..n)
            .map(|i| ServeRequest {
                id: i as u64,
                prompt: vec![],
                image_seed: i as u64,
                max_new_tokens: prng.range(0, 8),
                arrival_ns: if prng.range(0, 12) == 0 {
                    f64::NAN
                } else {
                    prng.uniform(0.0, 5e8)
                },
            })
            .collect();
        let run = |threads: usize| -> String {
            let mut srv = ShardedServer::new(&model, &cfg, policy.clone(), packages, route);
            srv.set_work_stealing(steal);
            srv.set_threads(threads);
            outcome_json(&srv.serve(requests.clone()))
        };
        let (seq, exec) = (run(1), run(threads));
        if seq != exec {
            return Err(format!(
                "executor drain diverged (packages {packages}, threads {threads}, \
                 steal {steal}):\nsequential:\n{seq}\nexecutor:\n{exec}"
            ));
        }
        Ok(())
    });
}

/// A random chiplet endpoint over `packages` packages.
fn random_endpoint(prng: &mut Prng, packages: usize) -> chime::sim::fabric::Endpoint {
    use chime::sim::fabric::Endpoint;
    let p = prng.range(0, packages);
    if prng.bool() { Endpoint::dram(p) } else { Endpoint::rram(p) }
}

#[test]
fn prop_fabric_routes_are_symmetric_bounded_and_physical() {
    // Fabric routing invariants (sim::fabric::topology module docs), over
    // random topology kinds, package counts, and endpoint pairs:
    // (1) route(a, b) is the exact reversal of route(b, a);
    // (2) hop count never exceeds the topology's endpoint diameter;
    // (3) every hop is a physical link of the topology and no route
    //     crosses the same link twice;
    // (4) a route is empty iff src == dst.
    use chime::config::TopologyKind;
    use chime::sim::fabric::{Link, Topology};
    use std::collections::BTreeSet;

    check("fabric route invariants", |prng| {
        let packages = prng.range(1, 13);
        let kind = *prng.choice(&TopologyKind::ALL);
        let topo = kind.build(packages);
        let physical: BTreeSet<Link> = topo.links().into_iter().collect();
        let src = random_endpoint(prng, packages);
        let dst = random_endpoint(prng, packages);
        let fwd = topo.route(src, dst);
        let mut bwd = topo.route(dst, src);
        bwd.reverse();
        if fwd != bwd {
            return Err(format!(
                "{kind:?} n={packages}: {src:?}->{dst:?} is not the reversal of the \
                 opposite direction: {fwd:?} vs {bwd:?}"
            ));
        }
        if fwd.len() > topo.diameter() {
            return Err(format!(
                "{kind:?} n={packages}: {src:?}->{dst:?} takes {} hops, diameter {}",
                fwd.len(),
                topo.diameter()
            ));
        }
        let mut crossed = BTreeSet::new();
        for link in &fwd {
            if !physical.contains(link) {
                return Err(format!("{kind:?} n={packages}: {link:?} is not a physical link"));
            }
            if !crossed.insert(*link) {
                return Err(format!(
                    "{kind:?} n={packages}: {src:?}->{dst:?} crosses {link:?} twice"
                ));
            }
        }
        if (src == dst) != fwd.is_empty() {
            return Err(format!(
                "{kind:?} n={packages}: {src:?}->{dst:?} route emptiness is wrong: {fwd:?}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_fabric_transfers_conserve_bytes_across_links() {
    // Per-link byte conservation: after any sequence of routed transfers,
    // the sum of per-link byte counters equals sum(bytes * hops) over the
    // transfers, while the aggregate payload counter counts each transfer
    // once — the same split the legacy UcieLink drew between payload and
    // wire traffic.
    use chime::config::{TopologyKind, UcieConfig};
    use chime::sim::fabric::{Fabric, Topology};

    check("fabric per-link byte conservation", |prng| {
        let packages = prng.range(1, 9);
        let kind = *prng.choice(&TopologyKind::ALL);
        let mut fabric = Fabric::new(UcieConfig::default(), kind, packages, 0);
        let mut expected_link_bytes = 0u64;
        let mut expected_payload = 0u64;
        for _ in 0..prng.range(1, 20) {
            let src = random_endpoint(prng, packages);
            let dst = random_endpoint(prng, packages);
            let bytes = prng.range(0, 1_000_000) as u64;
            let hops = fabric.topology().route(src, dst).len();
            fabric.advance(prng.uniform(0.0, 1e4));
            let d = fabric.transfer(src, dst, bytes);
            if bytes == 0 || hops == 0 {
                if d.hops != 0 || d.stall_ns != 0.0 || d.energy_pj != 0.0 {
                    return Err(format!("{kind:?}: empty transfer was not free: {d:?}"));
                }
                continue;
            }
            expected_link_bytes += bytes * hops as u64;
            expected_payload += bytes;
            if d.hops != hops {
                return Err(format!("{kind:?}: delivery hops {} != route hops {hops}", d.hops));
            }
            if d.delivery_ns < d.stall_ns {
                return Err(format!(
                    "{kind:?}: receiver got the payload before the sender unstalled: {d:?}"
                ));
            }
        }
        let link_bytes: u64 = fabric.link_states().map(|(_, s)| s.bytes).sum();
        if link_bytes != expected_link_bytes {
            return Err(format!(
                "{kind:?} n={packages}: per-link bytes {link_bytes} != expected \
                 {expected_link_bytes}"
            ));
        }
        if fabric.bytes_transferred != expected_payload {
            return Err(format!(
                "{kind:?} n={packages}: payload counter {} != expected {expected_payload}",
                fabric.bytes_transferred
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_cycle_fidelity_bounds_first_order_with_identical_accounting() {
    // Fidelity cross-validation invariants, per random op sequence:
    // (1) lower bound — the cycle-accurate stream/write time is >= the
    //     first-order time for the same request (the analytic model is an
    //     idealized lower bound; the bound is float-exact by construction);
    // (2) accounting — used_bytes, KV residency, and the lifetime
    //     read/write/endurance ledgers are bit-identical across fidelities.
    use chime::config::{DramConfig, RramConfig};
    use chime::sim::memory::cycle::{CycleDramState, CycleRramState};
    use chime::sim::memory::{DramState, MemoryModel, RramState};

    check("cycle >= first-order + identical accounting", |prng| {
        // --- DRAM -------------------------------------------------------
        let mut fo = DramState::new(DramConfig::default());
        let classes = WeightClass::all_in_priority_order();
        for class in classes {
            if prng.bool() {
                let _ = fo.place_weights_classed(class, prng.range(1, 300_000_000) as u64);
            }
        }
        let mut cy = CycleDramState::new(fo.clone());
        for _ in 0..prng.range(1, 20) {
            match prng.range(0, 3) {
                0 => {
                    let class = *prng.choice(&classes);
                    let bytes = prng.range(1, 60_000_000) as u64;
                    let a = fo.weight_stream_ns_classed(class, bytes);
                    let b = cy.weight_stream_ns_classed(class, bytes);
                    if b < a {
                        return Err(format!("dram stream: cycle {b} < first-order {a}"));
                    }
                }
                1 => {
                    let bytes = prng.range(1, 5_000_000) as u64;
                    let off_a = fo.append_kv(bytes);
                    let off_b = cy.append_kv(bytes);
                    if off_a != off_b {
                        return Err(format!("append_kv offload {off_a} != {off_b}"));
                    }
                }
                _ => {
                    let parts = vec![
                        (prng.range(0, 5), prng.range(1, 4_000_000) as u64),
                        (prng.range(0, 5), prng.range(1, 4_000_000) as u64),
                    ];
                    let a = fo.kv_stream_ns(&parts);
                    let b = cy.kv_stream_ns(&parts);
                    if b < a {
                        return Err(format!("dram kv stream: cycle {b} < first-order {a}"));
                    }
                }
            }
        }
        if fo.used_bytes() != cy.used_bytes()
            || fo.bytes_read != cy.base.bytes_read
            || fo.bytes_written != cy.base.bytes_written
            || fo.kv_offloaded != cy.base.kv_offloaded
        {
            return Err("dram accounting diverged across fidelities".into());
        }

        // --- RRAM -------------------------------------------------------
        let mut fo = RramState::new(RramConfig::default());
        let mut cy = CycleRramState::new(fo.clone());
        let w = prng.range(1, 2_000_000_000) as u64;
        let a = fo.load_weights(w)?;
        let b = cy.load_weights(w)?;
        if b < a {
            return Err(format!("rram load: cycle {b} < first-order {a}"));
        }
        for _ in 0..prng.range(1, 15) {
            match prng.range(0, 3) {
                0 => {
                    let bytes = prng.range(1, 50_000_000) as u64;
                    let a = fo.weight_stream_ns(bytes);
                    let b = cy.weight_stream_ns(bytes);
                    if b < a {
                        return Err(format!("rram read: cycle {b} < first-order {a}"));
                    }
                }
                1 => {
                    let bytes = prng.range(1, 10_000_000) as u64;
                    let a = fo.offload_kv(bytes);
                    let b = cy.offload_kv(bytes);
                    if b < a {
                        return Err(format!("rram offload: cycle {b} < first-order {a}"));
                    }
                }
                _ => {
                    let bytes = prng.range(1, 10_000_000) as u64;
                    let a = fo.kv_stream_ns(bytes);
                    let b = cy.kv_stream_ns(bytes);
                    if b < a {
                        return Err(format!("rram kv: cycle {b} < first-order {a}"));
                    }
                }
            }
        }
        if fo.used_bytes() != cy.used_bytes()
            || fo.lifetime_read_bytes != cy.base.lifetime_read_bytes
            || fo.lifetime_write_bytes != cy.base.lifetime_write_bytes
            || fo.endurance_consumed().to_bits() != cy.endurance_consumed().to_bits()
        {
            return Err("rram accounting diverged across fidelities".into());
        }
        Ok(())
    });
}

#[test]
fn prop_arrival_spec_round_trips() {
    // `ArrivalProcess::spec()` is the canonical spelling: parsing it back
    // must reproduce the exact process (bit-exact Poisson rate — Rust's
    // f64 Display emits the shortest round-trippable form — and the
    // verbatim trace path), across rates spanning ten orders of magnitude
    // and hostile path charsets (colons, dots, slashes).
    use chime::coordinator::ArrivalProcess;

    check("arrival spec round-trip", |prng| {
        let p = match prng.range(0, 3) {
            0 => ArrivalProcess::Burst,
            1 => {
                let rate_per_s = prng.uniform(0.1, 10.0) * 10f64.powf(prng.uniform(-3.0, 7.0));
                ArrivalProcess::Poisson { rate_per_s }
            }
            _ => {
                let charset = ['a', 'z', '0', '_', '-', '.', '/', ':'];
                let len = prng.range(1, 24);
                let path: String = (0..len).map(|_| *prng.choice(&charset)).collect();
                ArrivalProcess::Trace { path }
            }
        };
        let spec = p.spec();
        let back = ArrivalProcess::parse(&spec)
            .map_err(|e| format!("canonical spec {spec:?} failed to parse: {e}"))?;
        if back != p {
            return Err(format!("round-trip mismatch: {p:?} -> {spec:?} -> {back:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_trace_spans_are_well_nested_and_conserving() {
    // Observability invariants (DESIGN.md §14), per random traced serving
    // case over topology kinds, package counts, queue policies, and both
    // steal modes:
    // (1) well-nestedness — spans on each (package, track) timeline are
    //     sequential, non-overlapping virtual intervals with monotone
    //     start times, and every timestamp/duration is finite and >= 0;
    // (2) mirroring — the Serving track carries exactly one instant per
    //     streamed ServeEvent, kind for kind;
    // (3) conservation — Σ `fabric_leg` bytes in the trace args, grouped
    //     by link label, equals the per-link fabric byte counters exactly.
    use chime::config::{ChimeConfig, TopologyKind, WorkloadConfig};
    use chime::coordinator::{BatchPolicy, RoutePolicy, ServeRequest, ShardedServer};
    use chime::obs::{link_label, Track};
    use std::collections::BTreeMap;

    let model = MllmConfig::tiny();
    let mut cfg = ChimeConfig::default();
    cfg.workload = WorkloadConfig { image_size: 64, text_tokens: 8, output_tokens: 4 };

    check("trace well-nestedness + conservation", |prng| {
        let packages = prng.range(1, 4);
        cfg.hardware.topology.kind = *prng.choice(&TopologyKind::ALL);
        let route = if prng.bool() { RoutePolicy::RoundRobin } else { RoutePolicy::LeastLoaded };
        let policy = BatchPolicy {
            max_batch: prng.range(1, 4),
            queue_capacity: prng.range(1, 10),
        };
        let n = prng.range(1, 10);
        let requests: Vec<ServeRequest> = (0..n)
            .map(|i| ServeRequest {
                id: i as u64,
                prompt: vec![],
                image_seed: i as u64,
                max_new_tokens: prng.range(0, 6),
                arrival_ns: prng.uniform(0.0, 5e8),
            })
            .collect();
        let mut srv = ShardedServer::new(&model, &cfg, policy, packages, route);
        srv.set_work_stealing(prng.bool());
        srv.set_tracing(true);
        let mut session = srv.open_serving();
        let mut kinds: BTreeMap<String, usize> = BTreeMap::new();
        for r in requests {
            for ev in session.submit(r) {
                *kinds.entry(ev.kind().to_string()).or_default() += 1;
            }
        }
        for ev in session.drain() {
            *kinds.entry(ev.kind().to_string()).or_default() += 1;
        }
        let out = session.finish();
        if out.responses.len() + out.shed.len() != n {
            return Err("traced drain lost requests".into());
        }
        let trace = srv.take_trace().expect("tracing was on");

        // (1) spans per (pid, track) timeline are monotone and disjoint.
        let mut cursor: BTreeMap<(usize, Track), f64> = BTreeMap::new();
        for r in trace.records() {
            if !r.start_ns.is_finite() || r.start_ns < 0.0 {
                return Err(format!("record {:?} has a bad start {}", r.name, r.start_ns));
            }
            let Some(dur) = r.dur_ns else { continue };
            if !dur.is_finite() || dur < 0.0 {
                return Err(format!("span {:?} has a bad duration {dur}", r.name));
            }
            let open = cursor.entry((r.pid, r.track)).or_insert(0.0);
            if r.start_ns < *open {
                return Err(format!(
                    "span {:?} on pid {} track {:?} starts at {} inside the previous \
                     span (open until {})",
                    r.name, r.pid, r.track, r.start_ns, open
                ));
            }
            *open = r.start_ns + dur;
        }

        // (2) one Serving-track instant per streamed protocol event.
        let mut traced_kinds: BTreeMap<String, usize> = BTreeMap::new();
        for r in trace.records() {
            if r.track == Track::Serving {
                *traced_kinds.entry(r.name.to_string()).or_default() += 1;
            }
        }
        if traced_kinds != kinds {
            return Err(format!(
                "serving instants {traced_kinds:?} != event stream {kinds:?}"
            ));
        }

        // (3) Σ fabric-leg bytes per link == the fabric link counters.
        let mut legs: BTreeMap<String, u64> = BTreeMap::new();
        for r in trace.records() {
            if r.name != "fabric_leg" {
                continue;
            }
            let link = r
                .args
                .iter()
                .find(|(k, _)| *k == "link")
                .and_then(|(_, v)| v.as_str())
                .ok_or_else(|| "fabric_leg instant without a link label".to_string())?
                .to_string();
            let bytes = r
                .args
                .iter()
                .find(|(k, _)| *k == "bytes")
                .and_then(|(_, v)| v.as_f64())
                .ok_or_else(|| "fabric_leg instant without a byte count".to_string())?;
            *legs.entry(link).or_default() += bytes as u64;
        }
        let counters: BTreeMap<String, u64> = srv
            .fabric_links()
            .iter()
            .filter(|(_, s)| s.bytes > 0)
            .map(|(l, s)| (link_label(l), s.bytes))
            .collect();
        if legs != counters {
            return Err(format!(
                "trace legs {legs:?} do not decompose the link counters {counters:?}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_prefill_cost_exceeds_single_decode_step() {
    check("prefill > decode step", |prng| {
        let llm = random_llm(prng);
        let s = prng.range(2, 512);
        let prefill: f64 = backbone::prefill_ops(&llm, s).iter().map(|o| o.flops).sum();
        let decode: f64 = backbone::decode_ops(&llm, s).iter().map(|o| o.flops).sum();
        if prefill <= decode {
            return Err(format!("prefill {prefill} <= decode {decode} at s={s}"));
        }
        Ok(())
    });
}
