//! Integration tests: the full simulation stack (config -> model ->
//! mapping -> sim -> baselines) against the paper's quantitative shape.

use chime::baselines::{facil, jetson};
use chime::config::{ChimeConfig, FacilSpec, JetsonSpec, MllmConfig, WorkloadConfig};
use chime::mapping::Plan;
use chime::sim::{self, SimEngine};

#[test]
fn paper_headline_shape_holds() {
    // Fig 6: CHIME beats Jetson 20-80x in TPS and >50x in tok/J for every
    // Table II model; CHIME power stays in the edge envelope.
    let cfg = ChimeConfig::default();
    let jspec = JetsonSpec::default();
    for m in MllmConfig::paper_models() {
        let c = sim::simulate(&m, &cfg);
        let j = jetson::run(&m, &cfg.workload, &jspec);
        let speedup = c.tokens_per_s() / j.tokens_per_s();
        let egain = c.tokens_per_j() / j.tokens_per_j();
        assert!((15.0..90.0).contains(&speedup), "{}: speedup {speedup}", m.name);
        assert!(egain > 50.0, "{}: energy gain {egain}", m.name);
        assert!(c.avg_power_w() < 4.0, "{}: {} W", m.name, c.avg_power_w());
        assert!(c.tokens_per_s() > 100.0 && c.tokens_per_s() < 900.0);
    }
}

#[test]
fn chime_beats_facil_on_every_model() {
    // Table V: 12.1-69.2x (cross-paired); per-model the ratio must be
    // large and positive.
    let cfg = ChimeConfig::default();
    let fspec = FacilSpec::default();
    for m in MllmConfig::paper_models() {
        let c = sim::simulate(&m, &cfg);
        let f = facil::run(&m, &cfg.workload, &fspec);
        let ratio = c.tokens_per_s() / f.tokens_per_s();
        assert!(ratio > 8.0, "{}: CHIME/FACIL {ratio}", m.name);
    }
}

#[test]
fn dram_only_ablation_in_paper_band() {
    // Fig 9: 2.38-2.49x speedup; we accept 1.7-3.0x as matching shape.
    let cfg = ChimeConfig::default();
    for m in MllmConfig::paper_models() {
        let het = sim::simulate(&m, &cfg);
        let solo = sim::simulate_dram_only(&m, &cfg);
        let speedup = het.tokens_per_s() / solo.tokens_per_s();
        assert!((1.7..3.0).contains(&speedup), "{}: {speedup}", m.name);
        // Energy-efficiency gain is modest (paper: 1.04-1.07x).
        let egain = het.tokens_per_j() / solo.tokens_per_j();
        assert!((0.8..1.8).contains(&egain), "{}: egain {egain}", m.name);
    }
}

#[test]
fn seqlen_scaling_monotone_and_ordered() {
    // Fig 8: latency/energy grow with context; big models sit above small.
    let cfg = ChimeConfig::default();
    let mut last = 0.0;
    for text in [128usize, 1024, 4096] {
        let w = WorkloadConfig { image_size: 512, text_tokens: text, output_tokens: 488 };
        let s = sim::simulate_with_workload(&MllmConfig::fastvlm_1_7b(), &cfg, &w);
        assert!(s.total_time_ns() > last);
        last = s.total_time_ns();
    }
    let w = WorkloadConfig { image_size: 512, text_tokens: 2048, output_tokens: 488 };
    let small = sim::simulate_with_workload(&MllmConfig::fastvlm_0_6b(), &cfg, &w);
    let big = sim::simulate_with_workload(&MllmConfig::mobilevlm_3b(), &cfg, &w);
    assert!(big.total_time_ns() > small.total_time_ns());
    assert!(big.total_energy_j() > small.total_energy_j());
}

#[test]
fn ttft_dominated_by_prefill_not_decode() {
    let cfg = ChimeConfig::default();
    let s = sim::simulate(&MllmConfig::fastvlm_0_6b(), &cfg);
    assert!(s.ttft_ns() < s.decode.time_ns);
    assert!(s.ttft_ns() > 0.0);
}

#[test]
fn energy_ledger_consistent_with_phases() {
    let cfg = ChimeConfig::default();
    let s = sim::simulate(&MllmConfig::mobilevlm_1_7b(), &cfg);
    let ledger_total = s.energy().total_joules();
    let phase_total = s.total_energy_j();
    assert!((ledger_total - phase_total).abs() / phase_total < 1e-9);
}

#[test]
fn engine_reusable_across_inferences() {
    // KV state accumulates; a fresh engine must match a fresh engine, and
    // endurance must accumulate monotonically across inferences.
    let cfg = ChimeConfig::default();
    let mut w = cfg.workload.clone();
    w.output_tokens = 32;
    let m = MllmConfig::mobilevlm_3b();
    let plan = Plan::build(&m, &cfg.hardware, &w);
    let mut engine = SimEngine::new(&cfg.hardware, &plan);
    let a = engine.run_inference(&plan);
    let e1 = engine.rram.endurance_consumed();
    let _b = engine.run_inference(&plan);
    let e2 = engine.rram.endurance_consumed();
    assert!(e2 >= e1);
    assert!(a.total_time_ns() > 0.0);
}

#[test]
fn workload_trace_counts_flow_through() {
    let cfg = ChimeConfig::default();
    let mut w = cfg.workload.clone();
    w.output_tokens = 17;
    let s = sim::simulate_with_workload(&MllmConfig::tiny(), &cfg, &w);
    assert_eq!(s.output_tokens, 17);
    assert_eq!(s.model, "tiny");
}

#[test]
fn calibration_knobs_change_results() {
    use chime::util::Json;
    let mut cfg = ChimeConfig::default();
    let base = sim::simulate(&MllmConfig::fastvlm_1_7b(), &cfg);
    cfg.apply_overrides(
        &Json::parse(r#"{"rram.near_layer_bw_mult": 1.0}"#).unwrap(),
    )
    .unwrap();
    let slowed = sim::simulate(&MllmConfig::fastvlm_1_7b(), &cfg);
    assert!(slowed.total_time_ns() > base.total_time_ns() * 1.2);
}
