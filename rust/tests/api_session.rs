//! Integration tests for the `chime::api` surface.
//!
//! Two guarantees:
//!
//! 1. **Bit-identity** — `Session`-driven runs serialize byte-identically
//!    (canonical JSON) to the pre-refactor direct calls for the sim,
//!    dram-only, and 2-package sharded paths, so the golden paper numbers
//!    cannot move under the API layer.
//! 2. **One contract** — every `Backend` (sim, dram-only, sharded,
//!    jetson, facil, and functional when artifacts exist) passes the same
//!    parametrized serve/infer contract: conservation, causality,
//!    determinism.

use chime::api::{BackendKind, ChimeError, ServeRequest, Session};
use chime::config::{ChimeConfig, MllmConfig, WorkloadConfig};
use chime::coordinator::{BatchPolicy, RoutePolicy, ServeOutcome, ShardedServer, SimulatedServer};
use chime::sim::{self, InferenceStats};
use chime::util::Json;

/// Canonical JSON for an inference (every float serialized in full).
fn stats_json(s: &InferenceStats) -> String {
    Json::obj(vec![
        ("model", s.model.as_str().into()),
        ("ttft_ns", s.ttft_ns().into()),
        ("total_ns", s.total_time_ns().into()),
        ("energy_j", s.total_energy_j().into()),
        ("tps", s.tokens_per_s().into()),
        ("tok_per_j", s.tokens_per_j().into()),
        ("power_w", s.avg_power_w().into()),
        ("kv_offloaded", (s.kv_offloaded_bytes as i64).into()),
        ("endurance", s.rram_endurance_consumed.into()),
        ("output_tokens", s.output_tokens.into()),
    ])
    .pretty()
}

/// Canonical JSON for a serve outcome (per-response timing + energy).
fn outcome_json(out: &ServeOutcome) -> String {
    let rows: Vec<Json> = out
        .responses
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("id", (r.id as i64).into()),
                ("tokens", r.tokens.len().into()),
                ("queue_ns", r.queue_ns.into()),
                ("ttft_ns", r.ttft_ns.into()),
                ("service_ns", r.service_ns.into()),
                ("energy_j", r.energy_j.into()),
            ])
        })
        .collect();
    Json::obj(vec![
        ("responses", Json::Arr(rows)),
        ("shed", Json::arr(out.shed.iter().map(|r| Json::from(r.id as i64)))),
        ("completed", (out.metrics.completed as i64).into()),
        ("rejected", (out.metrics.rejected as i64).into()),
        ("shed_count", (out.metrics.shed as i64).into()),
        ("tokens", (out.metrics.tokens as i64).into()),
        ("steals", (out.metrics.steals as i64).into()),
        ("stolen_bytes", (out.metrics.stolen_bytes as i64).into()),
    ])
    .pretty()
}

fn small_cfg() -> ChimeConfig {
    let mut cfg = ChimeConfig::default();
    cfg.workload = WorkloadConfig { image_size: 64, text_tokens: 8, output_tokens: 4 };
    cfg
}

fn small_builder(model: &MllmConfig) -> chime::api::SessionBuilder {
    Session::builder()
        .model_config(model.clone())
        .image_size(64)
        .text_tokens(8)
        .output_tokens(4)
}

#[test]
fn session_sim_infer_bit_identical_to_direct_call() {
    let mut cfg = ChimeConfig::default();
    cfg.workload.output_tokens = 16;
    let m = MllmConfig::fastvlm_0_6b();
    let direct = sim::simulate(&m, &cfg);
    let mut session = Session::builder()
        .model_config(m.clone())
        .output_tokens(16)
        .build()
        .unwrap();
    let via_api = session.infer().unwrap();
    assert_eq!(
        stats_json(&direct),
        stats_json(&via_api),
        "Session sim path drifted from sim::simulate"
    );
}

#[test]
fn session_dram_only_infer_bit_identical_to_direct_call() {
    let mut cfg = ChimeConfig::default();
    cfg.workload.output_tokens = 16;
    let m = MllmConfig::mobilevlm_3b();
    let direct = sim::simulate_dram_only(&m, &cfg);
    let mut session = Session::builder()
        .model_config(m.clone())
        .output_tokens(16)
        .backend(BackendKind::DramOnly)
        .build()
        .unwrap();
    let via_api = session.infer().unwrap();
    assert_eq!(
        stats_json(&direct),
        stats_json(&via_api),
        "Session dram-only path drifted from sim::simulate_dram_only"
    );
}

#[test]
fn session_sim_serve_bit_identical_to_simulated_server() {
    let model = MllmConfig::tiny();
    let cfg = small_cfg();
    let burst = ServeRequest::burst(6, 4);
    let mut direct_srv = SimulatedServer::new(&model, &cfg, BatchPolicy::default());
    let direct = direct_srv.serve(burst.clone());
    let mut session = small_builder(&model).build().unwrap();
    let via_api = session.serve(burst).unwrap();
    assert_eq!(
        outcome_json(&direct),
        outcome_json(&via_api),
        "Session serve path drifted from SimulatedServer"
    );
}

#[test]
fn session_sharded_serve_bit_identical_two_packages() {
    let model = MllmConfig::tiny();
    let cfg = small_cfg();
    let burst = ServeRequest::burst(8, 4);
    let mut direct_srv =
        ShardedServer::new(&model, &cfg, BatchPolicy::default(), 2, RoutePolicy::LeastLoaded);
    let direct = direct_srv.serve(burst.clone());
    let mut session = small_builder(&model)
        .backend(BackendKind::Sharded)
        .packages(2)
        .route(RoutePolicy::LeastLoaded)
        .build()
        .unwrap();
    let via_api = session.serve(burst).unwrap();
    assert_eq!(
        outcome_json(&direct),
        outcome_json(&via_api),
        "Session sharded path drifted from ShardedServer"
    );
}

/// The sessions the shared contract runs over. Functional joins only when
/// the AOT artifacts exist (CI builds them separately).
fn contract_sessions() -> Vec<(String, Session)> {
    let model = MllmConfig::tiny();
    let mut out = Vec::new();
    for kind in [BackendKind::Sim, BackendKind::DramOnly, BackendKind::Jetson, BackendKind::Facil]
    {
        let s = small_builder(&model).backend(kind).build().unwrap();
        out.push((format!("{kind:?}"), s));
    }
    let sharded = small_builder(&model)
        .backend(BackendKind::Sharded)
        .packages(2)
        .build()
        .unwrap();
    out.push(("Sharded(2)".to_string(), sharded));
    match Session::builder().backend(BackendKind::Functional).build() {
        Ok(s) => out.push(("Functional".to_string(), s)),
        Err(ChimeError::BackendUnavailable { .. }) => {
            eprintln!("skipping functional backend: artifacts not built")
        }
        Err(other) => panic!("unexpected functional build error: {other:?}"),
    }
    out
}

#[test]
fn streaming_sessions_match_batch_serve_bit_for_bit() {
    // `Backend::serve` is a provided drain-everything wrapper over
    // `open_serving`; a manually driven session must serialize to the
    // same canonical JSON on every deterministic backend. (Functional is
    // excluded for byte-identity — wall-clock times — but still checked
    // for token-event conservation below when artifacts exist.)
    let pairs = contract_sessions().into_iter().zip(contract_sessions());
    for ((name, mut batch), (_, mut streaming)) in pairs {
        let reqs = batch.poisson_requests(7, 50.0, 6, 3);
        let mut session = streaming.open_serving().unwrap();
        for r in reqs.clone() {
            session.submit(r);
        }
        let events = session.drain().unwrap();
        let streamed = session.finish().unwrap();
        // Event-count conservation holds on every backend.
        let count = |kind: &str| events.iter().filter(|e| e.kind() == kind).count();
        assert_eq!(
            count("admitted") + count("rejected") + count("shed"),
            6,
            "{name}: every request needs exactly one admission decision"
        );
        assert_eq!(count("completed"), streamed.responses.len(), "{name}");
        assert_eq!(count("token") as u64, streamed.metrics.tokens, "{name}");
        if streaming.backend_kind() == BackendKind::Functional {
            continue; // wall-clock: real but not byte-stable
        }
        let direct = batch.serve(reqs).unwrap();
        assert_eq!(
            outcome_json(&direct),
            outcome_json(&streamed),
            "{name}: streaming session drifted from the batch wrapper"
        );
    }
}

#[test]
fn every_backend_passes_the_shared_serve_contract() {
    for (name, mut session) in contract_sessions() {
        // Synthesized through the session so prompts are sized for the
        // backend (the functional artifacts validate prompt length).
        let reqs = session.poisson_requests(7, 50.0, 6, 3);
        let out = session.serve(reqs).unwrap_or_else(|e| panic!("{name}: serve failed: {e}"));
        assert_eq!(
            out.responses.len() + out.shed.len(),
            6,
            "{name}: requests must be conserved"
        );
        assert_eq!(
            out.metrics.completed + out.metrics.rejected + out.metrics.shed,
            out.metrics.offered(),
            "{name}: admission accounting must balance"
        );
        assert_eq!(out.metrics.offered(), 6, "{name}");
        for r in &out.responses {
            assert!(r.queue_ns >= 0.0, "{name}: negative queueing");
            assert!(r.service_ns >= r.ttft_ns, "{name}: service < ttft");
            assert_eq!(r.tokens.len(), 3, "{name}: wrong token count");
        }
    }
}

#[test]
fn rejected_and_shed_are_independent_across_engines() {
    // One NaN arrival in an otherwise-finite stream: every engine must
    // count it as `shed` (input validation), never as `rejected`
    // (backpressure), and conservation must hold with both counters.
    for (name, mut session) in contract_sessions() {
        let mut reqs = session.poisson_requests(7, 50.0, 6, 3);
        reqs[2].arrival_ns = f64::NAN;
        let out = session.serve(reqs).unwrap_or_else(|e| panic!("{name}: serve failed: {e}"));
        assert_eq!(out.metrics.shed, 1, "{name}: the NaN arrival counts as shed");
        assert_eq!(out.metrics.rejected, 0, "{name}: no backpressure in this stream");
        assert_eq!(out.metrics.offered(), 6, "{name}");
        assert_eq!(
            out.metrics.completed + out.metrics.rejected + out.metrics.shed,
            out.metrics.offered(),
            "{name}: conservation with both counters"
        );
        assert_eq!(out.shed.len(), 1, "{name}: the shed request is handed back");
        // poisson_requests assigns ids 0..n in order; index 2 was poisoned.
        assert_eq!(out.shed[0].id, 2, "{name}");
    }
}

#[test]
fn every_backend_serves_deterministically() {
    // Two identically-built sessions must produce byte-identical outcomes.
    // The functional backend is excluded: its service times are measured
    // wall-clock, which is real (and asserted for token-parity in
    // integration_runtime.rs) but not byte-stable.
    let run = || {
        contract_sessions()
            .into_iter()
            .filter(|(_, s)| s.backend_kind() != BackendKind::Functional)
            .map(|(name, mut s)| {
                let reqs = s.poisson_requests(7, 50.0, 5, 3);
                let out = s.serve(reqs).unwrap();
                (name, outcome_json(&out))
            })
            .collect::<Vec<_>>()
    };
    for ((name_a, a), (_, b)) in run().into_iter().zip(run()) {
        assert_eq!(a, b, "{name_a}: serve must be deterministic");
    }
}

#[test]
fn every_simulating_backend_passes_the_shared_infer_contract() {
    // Functional excluded: it measures wall clock per request and reports
    // `Unsupported` for one-shot inference (asserted below).
    for (name, mut session) in contract_sessions() {
        if session.backend_kind() == BackendKind::Functional {
            let err = session.infer().unwrap_err();
            assert!(
                matches!(err, ChimeError::Unsupported { .. }),
                "{name}: expected Unsupported, got {err:?}"
            );
            continue;
        }
        let stats = session.infer().unwrap_or_else(|e| panic!("{name}: infer failed: {e}"));
        assert_eq!(stats.output_tokens, 4, "{name}");
        assert!(stats.total_time_ns() > 0.0, "{name}");
        assert!(stats.total_energy_j() > 0.0, "{name}");
        assert!(stats.tokens_per_s() > 0.0, "{name}");
        assert!(stats.ttft_ns() <= stats.total_time_ns(), "{name}");
    }
}

#[test]
fn session_and_direct_calls_agree_on_paper_headline_ratio() {
    // The Fig 6 headline (CHIME vs Jetson speedup) must be identical
    // whether computed from direct calls or through Session backends.
    let mut cfg = ChimeConfig::default();
    cfg.workload.output_tokens = 32;
    let m = MllmConfig::fastvlm_0_6b();
    let direct_chime = sim::simulate(&m, &cfg);
    let direct_jet = chime::baselines::jetson::run(
        &m,
        &cfg.workload,
        &chime::config::JetsonSpec::default(),
    );
    let direct_ratio = direct_chime.tokens_per_s() / direct_jet.tokens_per_s();

    let mut chime_s = Session::builder()
        .model_config(m.clone())
        .output_tokens(32)
        .build()
        .unwrap();
    let mut jet_s = Session::builder()
        .model_config(m.clone())
        .output_tokens(32)
        .backend(BackendKind::Jetson)
        .build()
        .unwrap();
    let api_ratio =
        chime_s.infer().unwrap().tokens_per_s() / jet_s.infer().unwrap().tokens_per_s();
    assert!(
        (direct_ratio - api_ratio).abs() < 1e-9,
        "speedup drifted: direct {direct_ratio} vs api {api_ratio}"
    );
}
