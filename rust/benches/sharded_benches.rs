//! Benches for the multi-package sharded serving path: the cross-package
//! dispatch scheduler in isolation, and end-to-end sharded serves at 1/2/4
//! packages (tiny model, saturating burst) so scaling regressions show up
//! as bench-time regressions.

use chime::config::{ChimeConfig, MllmConfig, WorkloadConfig};
use chime::coordinator::pipeline::{schedule_dispatch, StepWork};
use chime::coordinator::{BatchPolicy, RoutePolicy, ServeRequest, ShardedServer};
use chime::util::bench::Bench;
use chime::util::Prng;

fn main() {
    println!("== CHIME sharded-serving benches ==\n");
    let mut b = Bench::quick();

    // --- cross-package dispatch scheduling ---------------------------------
    let mut prng = Prng::new(3);
    let jobs: Vec<StepWork> = (0..32)
        .map(|id| StepWork::new(id, prng.uniform(1e5, 1e6), prng.uniform(1e5, 1e6)))
        .collect();
    for packages in [1usize, 2, 4, 8] {
        let per_pkg: Vec<Vec<StepWork>> = (0..packages)
            .map(|p| jobs.iter().copied().skip(p).step_by(packages).collect())
            .collect();
        let name = format!("schedule_dispatch(32 jobs, {packages} pkg)");
        b.bench(&name, || schedule_dispatch(&per_pkg));
        let step = schedule_dispatch(&per_pkg);
        println!(
            "  {packages} packages: step span {:.2} ms (serial {:.2} ms)",
            step.makespan_ns / 1e6,
            step.serial_ns / 1e6
        );
    }
    println!();

    // --- end-to-end sharded serve (tiny model, virtual time) ---------------
    let model = MllmConfig::tiny();
    let mut cfg = ChimeConfig::default();
    cfg.workload = WorkloadConfig { image_size: 64, text_tokens: 8, output_tokens: 16 };
    for packages in [1usize, 2, 4] {
        let name = format!("sharded_serve(tiny, 16 reqs, {packages} pkg)");
        b.bench(&name, || {
            let mut srv = ShardedServer::new(
                &model,
                &cfg,
                BatchPolicy::default(),
                packages,
                RoutePolicy::LeastLoaded,
            );
            let out = srv.serve(ServeRequest::burst(16, 16));
            assert_eq!(out.responses.len(), 16);
            out.metrics.tokens
        });
    }

    print!("{}", b.summary());
}
