//! Hot-path micro-benchmarks for the §Perf optimization pass (L3):
//! the simulator's inner loops (plan -> decode kernels -> chiplet costs),
//! the mapping fusion pass, the serving tick, and the substrates.

use chime::config::{ChimeConfig, MllmConfig};
use chime::coordinator::pipeline::{schedule_tick, StepWork};
use chime::mapping::{fusion, Plan};
use chime::model::backbone;
use chime::sim::SimEngine;
use chime::util::bench::Bench;
use chime::util::{Json, Prng};

fn main() {
    println!("== CHIME hot-path benches ==\n");
    let mut b = Bench::new();
    let cfg = ChimeConfig::default();

    // --- simulator hot loop ------------------------------------------------
    let model = MllmConfig::mobilevlm_3b();
    let plan = Plan::build(&model, &cfg.hardware, &cfg.workload);
    let mut engine = SimEngine::new(&cfg.hardware, &plan);
    let pos = plan.trace.prefill_len();

    b.bench("decode_ops_generation(3B)", || backbone::decode_ops(&model.llm, pos));
    let ops = backbone::decode_ops(&model.llm, pos);
    b.bench("fusion_pass(3B step)", || fusion::fuse_ops(&ops, 1));
    let kernels = plan.decode_kernels(pos);
    b.bench("sim_decode_step(3B)", || engine.run_kernels(&kernels));
    b.bench("plan_decode_kernels(3B)", || plan.decode_kernels(pos));
    let mut tmpl = plan.decode_template();
    b.bench("plan_patch_template(3B) [opt]", || {
        plan.patch_decode_template(&mut tmpl, pos);
        tmpl.kernels.len()
    });
    plan.patch_decode_template(&mut tmpl, pos);
    b.bench("sim_decode_step_template(3B) [opt]", || engine.run_kernels(&tmpl.kernels));

    // Full-inference simulation (short decode for bounded bench time).
    let mut short = cfg.clone();
    short.workload.output_tokens = 32;
    b.bench("simulate_inference(0.6B, 32 tok)", || {
        chime::sim::simulate(&MllmConfig::fastvlm_0_6b(), &short)
    });

    // --- coordinator -------------------------------------------------------
    let mut prng = Prng::new(1);
    let jobs: Vec<StepWork> = (0..8)
        .map(|id| StepWork {
            id,
            dram_ns: prng.uniform(1e5, 1e6),
            rram_ns: prng.uniform(1e5, 1e6),
        })
        .collect();
    b.bench("johnson_schedule_tick(8 jobs)", || schedule_tick(&jobs));

    // --- substrates ---------------------------------------------------------
    let manifest_text = std::fs::read_to_string("artifacts/manifest.json").ok();
    if let Some(text) = manifest_text {
        b.bench("json_parse(manifest)", || Json::parse(&text).unwrap());
    }
    let blob = {
        let mut p = Prng::new(7);
        let arr: Vec<Json> = (0..1000)
            .map(|i| {
                Json::obj(vec![
                    ("id", (i as i64).into()),
                    ("x", p.f64().into()),
                    ("name", format!("row-{i}").into()),
                ])
            })
            .collect();
        Json::Arr(arr)
    };
    b.bench("json_serialize(1k rows)", || blob.pretty());

    print!("{}", b.summary());
}
