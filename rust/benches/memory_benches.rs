//! Memory-subsystem benches: the cost of cycle-accurate fidelity.
//!
//! Prices the raw stream queries (first-order vs the bank/row and
//! mat/pulse state machines) and a full inference at both fidelities, so
//! the overhead of `--memory cycle` stays measured.

use chime::config::{ChimeConfig, DramConfig, MemoryFidelity, MllmConfig, RramConfig};
use chime::sim::memory::cycle::{CycleDramState, CycleRramState};
use chime::sim::memory::dram::WeightClass;
use chime::sim::memory::{DramState, RramState};
use chime::util::bench::Bench;

fn main() {
    println!("== CHIME memory-fidelity benches ==\n");
    let mut b = Bench::new();

    // --- raw DRAM stream queries ----------------------------------------
    let mut fo_dram = DramState::new(DramConfig::default());
    fo_dram.place_weights(2_000_000_000).unwrap();
    let mut cy_dram = CycleDramState::new(fo_dram.clone());
    b.bench("dram_stream_first_order(64MB)", || {
        fo_dram.weight_stream_ns_classed(WeightClass::Attn, 64_000_000)
    });
    b.bench("dram_stream_cycle(64MB)", || {
        cy_dram.weight_stream_ns_classed(WeightClass::Attn, 64_000_000)
    });
    b.bench("dram_kv_stream_cycle(3 tiers)", || {
        cy_dram.kv_stream_ns(&[(0, 4_000_000), (1, 2_000_000), (2, 1_000_000)])
    });

    // --- raw RRAM stream queries ----------------------------------------
    let mut fo_rram = RramState::new(RramConfig::default());
    fo_rram.load_weights(4_000_000_000).unwrap();
    let mut cy_rram = CycleRramState::new(fo_rram.clone());
    b.bench("rram_stream_first_order(106MB)", || fo_rram.weight_stream_ns(106_000_000));
    b.bench("rram_stream_cycle(106MB)", || cy_rram.weight_stream_ns(106_000_000));

    // --- end-to-end inference at both fidelities ------------------------
    let mut cfg = ChimeConfig::default();
    cfg.workload.output_tokens = 32;
    let model = MllmConfig::fastvlm_0_6b();
    b.bench("simulate_first_order(0.6B, 32 tok)", || chime::sim::simulate(&model, &cfg));
    let mut cycle_cfg = cfg.clone();
    cycle_cfg.hardware.memory_fidelity = MemoryFidelity::CycleAccurate;
    b.bench("simulate_cycle(0.6B, 32 tok)", || chime::sim::simulate(&model, &cycle_cfg));

    print!("{}", b.summary());
}
