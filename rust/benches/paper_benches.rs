//! Paper-results benchmarks: one bench per evaluation table/figure.
//!
//! Each bench times the end-to-end regeneration of an experiment (the
//! same code `chime results` runs) AND prints the reproduced rows, so
//! `cargo bench` doubles as the artifact-regeneration harness
//! (deliverable (d) in DESIGN.md).

use chime::results;
use chime::util::bench::Bench;

fn main() {
    println!("== CHIME paper benches (one per table/figure) ==\n");
    let mut b = Bench::quick();

    // Print each experiment once (the reproduced numbers), then time it.
    for (id, runner) in [
        ("fig1_breakdown", results::fig1::run as fn() -> results::Experiment),
        ("fig6_speedup_energy", results::fig6::run),
        ("table5_platforms", results::table5::run),
        ("fig7_area_power", results::fig7::run),
        ("fig8_seqlen", results::fig8::run),
        ("fig9_memcfg", results::fig9::run),
        ("scaling_packages", results::scaling::run),
        ("memcheck_fidelity", results::memcheck::run),
        ("tail_work_stealing", results::tail::run),
        // Quick config (tiny model): the full matrix is `chime bench`;
        // timing the timer at paper scale would double cargo-bench time.
        ("perf_simulator_quick", || results::perf::run_with(&results::perf::BenchConfig::quick())),
    ] {
        let e = runner();
        println!("{}", e.text);
        b.bench(id, runner);
        println!();
    }

    print!("{}", b.summary());
}
