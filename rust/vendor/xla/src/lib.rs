//! API-compatible **stub** of the `xla` crate surface the `chime` runtime
//! uses (PJRT CPU client + HLO-text compilation + literals).
//!
//! The real `xla` crate wraps `xla_extension`, a large C++ build closure
//! that is not present in this offline environment. This stub keeps the
//! whole functional-runtime code path *compiling* unchanged while making
//! the capability probe fail fast: `PjRtClient::cpu()` returns an error,
//! so `FunctionalMllm::load` / `FunctionalServer::load` report the PJRT
//! backend as unavailable and every artifact-gated test skips cleanly —
//! exactly the behaviour the gated tests already expect when
//! `make artifacts` has not run.
//!
//! To enable the real functional path, point the `xla` path dependency in
//! `rust/Cargo.toml` at a checkout of the real crate (the API below is a
//! strict subset of it); no `chime` source changes are needed.

use std::fmt;

/// Stub error: every operation reports the backend as unavailable.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT/xla_extension is not available in this build \
             (vendored stub; see rust/vendor/xla/src/lib.rs)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// A host literal (stub: carries no data; constructible so call sites
/// type-check, but every readback errors).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T>(_v: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// Device buffer handle (stub: never constructed).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (stub: never constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (stub: creation always fails — the capability probe).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module proto (stub: parsing always fails).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("not available"), "{msg}");
    }

    #[test]
    fn literals_constructible_but_unreadable() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]);
        assert!(l.is_err());
        let s = Literal::scalar(3i32);
        assert!(s.to_vec::<i32>().is_err());
        assert!(s.to_tuple().is_err());
    }
}
