//! Minimal, API-compatible subset of the `anyhow` crate, vendored because
//! this offline environment cannot fetch registry crates (DESIGN.md §2
//! substitution table). It provides exactly what the `chime` crate uses:
//!
//! * [`Error`]: an opaque error with a context chain;
//! * [`Result<T>`]: alias for `std::result::Result<T, Error>`;
//! * [`anyhow!`] / [`bail!`]: ad-hoc error construction macros;
//! * [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * `From<E: std::error::Error>` so `?` converts concrete errors.
//!
//! Display mirrors real anyhow: `{}` prints the outermost context, `{:#}`
//! prints the whole chain joined with `: `, and `{:?}` prints the chain as
//! a `Caused by:` list (what `main() -> anyhow::Result<()>` shows).

use std::fmt;

/// Opaque error: a root message plus contexts, outermost last.
pub struct Error {
    /// `[0]` is the root cause; later entries are contexts wrapped around
    /// it (so the *outermost* description is the last element).
    chain: Vec<String>,
}

/// `Result` specialised to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.chain.push(ctx.to_string());
        self
    }

    /// The context chain, outermost first (mirrors `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(|s| s.as_str())
    }

    /// The root cause message.
    pub fn root_cause(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: whole chain, outermost first, `: `-joined.
            let joined: Vec<&str> = self.chain().collect();
            write!(f, "{}", joined.join(": "))
        } else {
            write!(f, "{}", self.chain.last().unwrap())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.last().unwrap())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in self.chain[..self.chain.len() - 1].iter().rev() {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: deliberately *not* `impl std::error::Error for Error` — exactly
// like real anyhow — so the blanket `From` below does not conflict with
// the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve source() chains as context entries.
        let mut chain = Vec::new();
        let mut cur: Option<&(dyn std::error::Error)> = Some(&e);
        while let Some(c) = cur {
            chain.push(c.to_string());
            cur = c.source();
        }
        chain.reverse(); // root cause first
        Error { chain }
    }
}

/// Attach context to fallible values (subset of `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<i32> {
            let n: i32 = "42".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 42);
        fn failing() -> Result<i32> {
            let n: i32 = "nope".parse()?;
            Ok(n)
        }
        assert!(failing().is_err());
    }

    #[test]
    fn macros() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let x = 7;
        let e = anyhow!("value {x} and {}", 8);
        assert_eq!(format!("{e}"), "value 7 and 8");
        fn bails() -> Result<()> {
            bail!("stopped at {}", 3);
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "stopped at 3");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }
}
