# CHIME reproduction — top-level targets.
#
#   make artifacts   AOT-lower the tiny MLLM to artifacts/ (needs JAX)
#   make build       release build of the Rust workspace
#   make test        tier-1 verify: cargo build --release && cargo test -q
#   make pytest      python kernel/model/AOT tests (skip cleanly w/o JAX)
#   make results     regenerate every paper table/figure
#   make golden      refresh the committed golden JSON snapshots
#   make memcheck    cross-validate first-order vs cycle-accurate memory
#   make tail        streaming-serve smoke (poisson arrivals + stealing, 2 fidelities)
#   make fabric      routed-fabric grid: steals + per-link peaks, pkgs x topologies
#   make serve-smoke HTTP/SSE listener + loadgen round trip, 2 fidelities
#   make trace-smoke record + sanity-check Chrome traces, 2 fidelities
#   make exec-smoke  parallel executor: deterministic + wall-clock, 2 fidelities
#   make bench-snapshot  write the simulator perf snapshot to BENCH_$(PR).json
#   make hotpath-snapshot  write the serving hot-path profile to HOTPATH_$(PR).json
#   make api-smoke   run every example through the chime::api::Session path
#   make docs        build the public-API docs (missing docs denied on api)

# PR number stamped into the snapshot filenames (results::perf::PR).
PR := 010

.PHONY: artifacts build test pytest results golden memcheck tail fabric serve-smoke trace-smoke exec-smoke bench-snapshot hotpath-snapshot api-smoke docs

artifacts:
	cd python && python -m compile.aot --outdir ../artifacts

build:
	cd rust && cargo build --release

test:
	cd rust && cargo build --release && cargo test -q

pytest:
	cd python && python -m pytest -q

results: build
	cd rust && cargo run --release -- results --all

golden:
	cd rust && CHIME_UPDATE_GOLDEN=1 cargo test --test golden_paper

# First-order vs cycle-accurate memory cross-validation (DESIGN.md §9);
# the same divergence table the golden test locks to a tolerance band.
memcheck: build
	cd rust && cargo run --release -- memcheck

# Streaming-serve smoke: the open-loop Poisson arrival process with work
# stealing, at both memory fidelities (DESIGN.md §10; the full
# tail-latency table is `chime results --fig tail`, locked by
# golden_tail_work_stealing).
tail: build
	cd rust && cargo run --release -- serve --arrival poisson:8 --steal on \
		--packages 4 --requests 8 --tokens 16 --model tiny --text 8 --out 4
	cd rust && cargo run --release -- serve --arrival poisson:8 --steal on \
		--packages 4 --requests 8 --tokens 16 --model tiny --text 8 --out 4 \
		--memory cycle

# Routed UCIe fabric grid (DESIGN.md §12): steals, stolen KB, routed
# steal delay, p99 latency, and per-link peak GB/s across {1,2,4,8}
# packages × the four topologies, stealing on; locked by
# golden_fabric_topologies.
fabric: build
	cd rust && cargo run --release -- results --fig fabric

# Network-serving smoke (DESIGN.md §13): bring up the HTTP/SSE listener
# on an ephemeral loopback port, drive it with the open-loop wall-clock
# load generator, and shut it down cleanly — at both memory fidelities.
# The listener writes its bound address to a file so the recipe never
# races the bind.
serve-smoke: build
	@set -e; cd rust; \
	for mem in first-order cycle; do \
		addr_file=target/serve_addr.txt; rm -f $$addr_file; \
		./target/release/chime serve --listen 127.0.0.1:0 \
			--addr-file $$addr_file --model tiny --text 8 --out 4 \
			--memory $$mem & \
		server=$$!; \
		for i in $$(seq 1 100); do \
			[ -s $$addr_file ] && break; sleep 0.1; \
		done; \
		[ -s $$addr_file ] || { echo "serve-smoke: listener never came up"; kill $$server; exit 1; }; \
		./target/release/chime loadgen --target $$(cat $$addr_file) \
			--requests 6 --arrival poisson:20 --tokens 8 --shutdown \
			|| { kill $$server 2>/dev/null; exit 1; }; \
		wait $$server; \
	done

# Observability smoke (DESIGN.md §14): record a Chrome trace from the
# single-inference and streaming-serve paths at both memory fidelities
# and require a well-formed traceEvents document with fabric-leg
# instants. The byte-determinism *gate* is
# traces_are_deterministic_and_sessions_start_fresh (library) and
# serve_trace_out_writes_a_deterministic_chrome_trace (net) in
# `make test`.
trace-smoke: build
	@set -e; cd rust; \
	for mem in first-order cycle; do \
		trace=target/trace_smoke_$$mem.json; rm -f $$trace; \
		./target/release/chime simulate --model tiny --text 8 --out 4 \
			--memory $$mem --trace-out $$trace; \
		grep -q '"traceEvents"' $$trace; \
		grep -q '"decode"' $$trace; \
		rm -f $$trace; \
		./target/release/chime serve --arrival poisson:8 --steal on \
			--packages 4 --topology ring --requests 8 --tokens 16 \
			--model tiny --text 8 --out 4 --memory $$mem --trace-out $$trace; \
		grep -q '"traceEvents"' $$trace; \
		grep -q '"fabric_leg"' $$trace; \
		rm -f $$trace; \
	done

# Parallel executor smoke (DESIGN.md §15): the deterministic windowed
# drain (--threads 4, outcome bit-identical to --threads 1 — the gate is
# prop_exec_drain_is_bit_identical_to_sequential in `make test`) and the
# free-running wall-clock executor (--wall, conservation-gated), at both
# memory fidelities.
exec-smoke: build
	@set -e; cd rust; \
	for mem in first-order cycle; do \
		./target/release/chime serve --packages 4 --requests 8 --tokens 16 \
			--arrival poisson:8 --model tiny --text 8 --out 4 \
			--memory $$mem --threads 4; \
		./target/release/chime serve --packages 4 --requests 8 --tokens 16 \
			--arrival poisson:8 --model tiny --text 8 --out 4 \
			--memory $$mem --threads 4 --wall; \
	done

# Simulator wall-clock benchmark (DESIGN.md §11): events/s and simulated
# tok/s per backend × memory fidelity over the Table II zoo, written as
# canonical JSON. Wall numbers are machine-dependent — the snapshot is a
# per-PR trajectory (EXPERIMENTS.md), not a golden file.
bench-snapshot: build
	cd rust && cargo run --release -- bench --snapshot ../BENCH_$(PR).json

# Serving hot-path wall-clock profile (ROADMAP item 4, DESIGN.md §14):
# wall time per instrumented span class (tick / submit / steal_pass)
# over the sharded serve loop at both memory fidelities, written as
# canonical JSON. Like the bench snapshot, a per-PR trajectory —
# machine-dependent wall numbers, not a golden file.
hotpath-snapshot: build
	cd rust && cargo run --release -- bench --quick --profile ../HOTPATH_$(PR).json

# Every example is a thin shell over chime::api::Session; running them
# end to end smoke-tests the whole public API surface.
api-smoke: build
	cd rust && cargo run --release --example quickstart -- --text 16 --out 8
	cd rust && cargo run --release --example vqa_serving -- --requests 2
	cd rust && cargo run --release --example seqlen_sweep
	cd rust && cargo run --release --example endurance_study

docs:
	cd rust && cargo doc --no-deps
