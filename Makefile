# CHIME reproduction — top-level targets.
#
#   make artifacts   AOT-lower the tiny MLLM to artifacts/ (needs JAX)
#   make build       release build of the Rust workspace
#   make test        tier-1 verify: cargo build --release && cargo test -q
#   make pytest      python kernel/model/AOT tests (skip cleanly w/o JAX)
#   make results     regenerate every paper table/figure
#   make golden      refresh the committed golden JSON snapshots

.PHONY: artifacts build test pytest results golden

artifacts:
	cd python && python -m compile.aot --outdir ../artifacts

build:
	cd rust && cargo build --release

test:
	cd rust && cargo build --release && cargo test -q

pytest:
	cd python && python -m pytest -q

results: build
	cd rust && cargo run --release -- results --all

golden:
	cd rust && CHIME_UPDATE_GOLDEN=1 cargo test --test golden_paper
