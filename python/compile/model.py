"""L2: functional MLLM (vision encoder -> connector -> LLM backbone).

This is the *functional-path* model of the CHIME reproduction (DESIGN.md §1):
a tiny (~0.9M-param) multimodal LLM whose forward pass is built from the
paper's Table I fused kernels, AOT-lowered per entry point and executed by
the Rust coordinator through PJRT. Timing/energy for the paper-scale models
(FastVLM / MobileVLM) comes from the Rust simulator; this model proves the
three layers compose and gives the coordinator real tokens to serve.

Dataflow mirrors the paper's two-cut-point mapping: within each decoder
layer only `attn_out` (DRAM->RRAM) and `ffn_out` (RRAM->DRAM) cross a fused
kernel boundary; everything else stays inside a kernel.

Entry points (all functional, weights baked at lowering):
  vision_encoder(image)                -> visual features
  connector(feats)                     -> pseudo tokens
  prefill(pseudo, text_ids)            -> (last-pos logits, K, V)
  decode_step(tok, pos, K, V)          -> (logits, K', V')
  model_smoke(image, text_ids)         -> first logits (single fused graph)
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fused_attn_stream, fused_ffn_act, fused_norm, fused_qkv_proj


@dataclass(frozen=True)
class TinyMLLMConfig:
    """Functional-model shape config (kept small so CPU PJRT executes it)."""

    d_model: int = 64
    n_heads: int = 4
    d_head: int = 16
    n_layers: int = 2           # LLM backbone depth
    d_ffn: int = 256
    vocab: int = 256
    img_size: int = 16          # square input image
    img_channels: int = 3
    patch: int = 4              # -> (img_size/patch)^2 = 16 visual tokens
    n_vis_layers: int = 2       # vision-encoder depth
    prompt_len: int = 16        # text tokens in the canned VQA prompt
    max_len: int = 64           # KV-cache capacity
    # seed 2 chosen because its greedy trajectory visits several distinct
    # tokens before settling — a stronger parity oracle than a degenerate
    # all-zeros sequence (random tiny transformers collapse quickly).
    seed: int = 2

    @property
    def n_vis_tokens(self) -> int:
        return (self.img_size // self.patch) ** 2

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.img_channels

    @property
    def prefill_len(self) -> int:
        return self.n_vis_tokens + self.prompt_len


DEFAULT_CONFIG = TinyMLLMConfig()


# ---------------------------------------------------------------------------
# Weights
# ---------------------------------------------------------------------------

def _layer_weights(key, d, dq, dkv, f):
    ks = jax.random.split(key, 8)
    s = 1.0 / np.sqrt(d)
    sf = 1.0 / np.sqrt(f)
    return {
        "ln1_g": jnp.ones(d), "ln1_b": jnp.zeros(d),
        "wq": jax.random.normal(ks[0], (d, dq)) * s,
        "bq": jnp.zeros(dq),
        "wk": jax.random.normal(ks[1], (d, dkv)) * s,
        "bk": jnp.zeros(dkv),
        "wv": jax.random.normal(ks[2], (d, dkv)) * s,
        "bv": jnp.zeros(dkv),
        "wo": jax.random.normal(ks[3], (dq, d)) * s,
        "bo": jnp.zeros(d),
        "ln2_g": jnp.ones(d), "ln2_b": jnp.zeros(d),
        "w1": jax.random.normal(ks[4], (d, f)) * s,
        "b1": jnp.zeros(f),
        "w2": jax.random.normal(ks[5], (f, d)) * sf,
        "b2": jnp.zeros(d),
    }


def init_weights(cfg: TinyMLLMConfig = DEFAULT_CONFIG):
    """Deterministic synthetic weights (fixed seed -> reproducible tokens)."""
    key = jax.random.PRNGKey(cfg.seed)
    kv_dim = cfg.n_heads * cfg.d_head
    (k_emb, k_pos, k_vproj, k_vpos, k_conn1, k_conn2, k_vis, k_llm) = \
        jax.random.split(key, 8)
    d = cfg.d_model
    w = {
        "emb": jax.random.normal(k_emb, (cfg.vocab, d)) * 0.05,
        "pos": jax.random.normal(k_pos, (cfg.max_len, d)) * 0.02,
        "vis_proj": jax.random.normal(k_vproj, (cfg.patch_dim, d)) / np.sqrt(cfg.patch_dim),
        "vis_pos": jax.random.normal(k_vpos, (cfg.n_vis_tokens, d)) * 0.02,
        "conn_w1": jax.random.normal(k_conn1, (d, 2 * d)) / np.sqrt(d),
        "conn_b1": jnp.zeros(2 * d),
        "conn_w2": jax.random.normal(k_conn2, (2 * d, d)) / np.sqrt(2 * d),
        "conn_b2": jnp.zeros(d),
        "lnf_g": jnp.ones(d), "lnf_b": jnp.zeros(d),
        "vis_layers": [
            _layer_weights(k, d, kv_dim, kv_dim, cfg.d_ffn)
            for k in jax.random.split(k_vis, cfg.n_vis_layers)
        ],
        "llm_layers": [
            _layer_weights(k, d, kv_dim, kv_dim, cfg.d_ffn)
            for k in jax.random.split(k_llm, cfg.n_layers)
        ],
    }
    return w


def synthetic_image(cfg: TinyMLLMConfig = DEFAULT_CONFIG) -> np.ndarray:
    """Deterministic 'astronaut' stand-in, integer-exact so the Rust side
    regenerates bit-identical pixels: v = ((i*W + j)*C + c) % 11 / 11 - 0.5."""
    i = np.arange(cfg.img_size)[:, None, None]
    j = np.arange(cfg.img_size)[None, :, None]
    c = np.arange(cfg.img_channels)[None, None, :]
    idx = (i * cfg.img_size + j) * cfg.img_channels + c
    return (np.asarray(idx % 11, np.float32) / 11.0 - 0.5).astype(np.float32)


DEFAULT_PROMPT = np.array([3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8, 9, 7, 9, 3],
                          np.int32)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _split_heads(x, n_heads, d_head):
    # [S, H*Dh] -> [H, S, Dh]
    s = x.shape[0]
    return x.reshape(s, n_heads, d_head).transpose(1, 0, 2)


def _merge_heads(x):
    # [H, S, Dh] -> [S, H*Dh]
    h, s, dh = x.shape
    return x.transpose(1, 0, 2).reshape(s, h * dh)


def _attn_block(x, lw, cfg, *, kv_len, causal):
    """Pre-norm attention sub-block (DRAM-chiplet side of the cut point)."""
    h = fused_norm(x, lw["ln1_g"], lw["ln1_b"])
    q, k, v = fused_qkv_proj(h, lw["wq"], lw["bq"], lw["wk"], lw["bk"],
                             lw["wv"], lw["bv"])
    qh = _split_heads(q, cfg.n_heads, cfg.d_head)
    kh = _split_heads(k, cfg.n_heads, cfg.d_head)
    vh = _split_heads(v, cfg.n_heads, cfg.d_head)
    o = fused_attn_stream(qh, kh, vh, kv_len,
                          scale=1.0 / np.sqrt(cfg.d_head), causal=causal)
    attn_out = _merge_heads(o) @ lw["wo"] + lw["bo"]
    return x + attn_out


def _ffn_block(x, lw):
    """FFN sub-block (RRAM-chiplet side of the cut point)."""
    h = fused_norm(x, lw["ln2_g"], lw["ln2_b"])
    return x + fused_ffn_act(h, lw["w1"], lw["b1"], lw["w2"], lw["b2"])


def _encoder_block(x, lw, cfg):
    s = x.shape[0]
    x = _attn_block(x, lw, cfg, kv_len=s, causal=False)
    return _ffn_block(x, lw)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def vision_encoder(w, cfg, image):
    """image [H, W, C] -> visual features [n_vis_tokens, d_model]."""
    p = cfg.patch
    g = cfg.img_size // p
    patches = image.reshape(g, p, g, p, cfg.img_channels)
    patches = patches.transpose(0, 2, 1, 3, 4).reshape(g * g, cfg.patch_dim)
    x = patches @ w["vis_proj"] + w["vis_pos"]
    for lw in w["vis_layers"]:
        x = _encoder_block(x, lw, cfg)
    return fused_norm(x, w["lnf_g"], w["lnf_b"])


def connector(w, cfg, feats):
    """MLP projector: visual features -> pseudo tokens in the LM domain."""
    del cfg
    return fused_ffn_act(feats, w["conn_w1"], w["conn_b1"],
                         w["conn_w2"], w["conn_b2"])


def _empty_cache(cfg):
    shape = (cfg.n_layers, cfg.n_heads, cfg.max_len, cfg.d_head)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def _logits(w, x_last):
    h = fused_norm(x_last[None, :], w["lnf_g"], w["lnf_b"])[0]
    return h @ w["emb"].T


def prefill(w, cfg, pseudo, text_ids):
    """pseudo [n_vis, d], text_ids [prompt_len] i32 ->
    (logits [vocab], K, V) with K/V [L, H, max_len, Dh] filled at [:S]."""
    s = cfg.prefill_len
    x = jnp.concatenate([pseudo, w["emb"][text_ids]], axis=0) + w["pos"][:s]
    k_cache, v_cache = _empty_cache(cfg)
    for li, lw in enumerate(w["llm_layers"]):
        h = fused_norm(x, lw["ln1_g"], lw["ln1_b"])
        q, k, v = fused_qkv_proj(h, lw["wq"], lw["bq"], lw["wk"], lw["bk"],
                                 lw["wv"], lw["bv"])
        kh = _split_heads(k, cfg.n_heads, cfg.d_head)
        vh = _split_heads(v, cfg.n_heads, cfg.d_head)
        k_cache = jax.lax.dynamic_update_slice(k_cache, kh[None], (li, 0, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, vh[None], (li, 0, 0, 0))
        qh = _split_heads(q, cfg.n_heads, cfg.d_head)
        o = fused_attn_stream(qh, kh, vh, s,
                              scale=1.0 / np.sqrt(cfg.d_head), causal=True)
        x = x + _merge_heads(o) @ lw["wo"] + lw["bo"]
        x = _ffn_block(x, lw)
    return _logits(w, x[-1]), k_cache, v_cache


def decode_step(w, cfg, tok, pos, k_cache, v_cache):
    """One autoregressive step. tok, pos: i32 scalars; K/V as from prefill.
    Appends this step's K/V at `pos` and attends over the kv_len = pos+1
    prefix (the paper's Tier-0-hot append-only KV discipline)."""
    x = (w["emb"][tok] + w["pos"][pos])[None, :]  # [1, d]
    for li, lw in enumerate(w["llm_layers"]):
        h = fused_norm(x, lw["ln1_g"], lw["ln1_b"])
        q, k, v = fused_qkv_proj(h, lw["wq"], lw["bq"], lw["wk"], lw["bk"],
                                 lw["wv"], lw["bv"])
        kh = _split_heads(k, cfg.n_heads, cfg.d_head)  # [H, 1, Dh]
        vh = _split_heads(v, cfg.n_heads, cfg.d_head)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, kh[None], (li, 0, pos, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, vh[None], (li, 0, pos, 0))
        qh = _split_heads(q, cfg.n_heads, cfg.d_head)
        o = fused_attn_stream(qh, k_cache[li], v_cache[li], pos + 1,
                              scale=1.0 / np.sqrt(cfg.d_head), causal=False)
        x = x + _merge_heads(o) @ lw["wo"] + lw["bo"]
        x = _ffn_block(x, lw)
    return _logits(w, x[0]), k_cache, v_cache


def model_smoke(w, cfg, image, text_ids):
    """Single fused graph: image + prompt -> first-token logits.

    This is the Makefile's `model.hlo.txt` smoke artifact — it exercises
    every fused kernel and the full encoder->connector->backbone dataflow
    in one compile unit."""
    feats = vision_encoder(w, cfg, image)
    pseudo = connector(w, cfg, feats)
    logits, _, _ = prefill(w, cfg, pseudo, text_ids)
    return logits


# ---------------------------------------------------------------------------
# Python-side greedy generation (parity oracle for the Rust coordinator)
# ---------------------------------------------------------------------------

def generate(w, cfg, image, text_ids, n_steps):
    """Greedy decode. Returns list of generated token ids (ints)."""
    feats = vision_encoder(w, cfg, jnp.asarray(image))
    pseudo = connector(w, cfg, feats)
    logits, k_cache, v_cache = prefill(w, cfg, pseudo, jnp.asarray(text_ids))
    toks = []
    pos = cfg.prefill_len
    for _ in range(n_steps):
        tok = int(jnp.argmax(logits))
        toks.append(tok)
        logits, k_cache, v_cache = decode_step(
            w, cfg, jnp.asarray(tok, jnp.int32), jnp.asarray(pos, jnp.int32),
            k_cache, v_cache)
        pos += 1
    return toks
