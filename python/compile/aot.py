"""AOT compile path: lower each MLLM entry point to HLO *text* + manifest.

Python runs ONCE here (`make artifacts`); the Rust coordinator then loads
`artifacts/*.hlo.txt` via the xla crate's PJRT CPU client and never calls
back into Python.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Weights are baked into the artifacts as constants from a fixed seed, so the
Rust binary is fully self-contained. `manifest.json` records every entry
point's signature plus a greedy-decode parity oracle the Rust integration
tests assert against.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    `as_hlo_text(True)` = print_large_constants: the default printer elides
    big literals as `constant({...})`, which the Rust-side text parser
    would silently read back as zeros — the baked weights MUST be dumped
    in full for the artifact to be self-contained.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def _sig(specs):
    out = []
    for name, s in specs:
        out.append({
            "name": name,
            "dtype": str(np.dtype(s.dtype)),
            "shape": list(s.shape),
        })
    return out


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entry_points(w, cfg):
    """Entry-point name -> (callable over arrays only, [(arg name, spec)])."""
    kv_shape = (cfg.n_layers, cfg.n_heads, cfg.max_len, cfg.d_head)
    img = _spec((cfg.img_size, cfg.img_size, cfg.img_channels))
    feats = _spec((cfg.n_vis_tokens, cfg.d_model))
    ids = _spec((cfg.prompt_len,), jnp.int32)
    scalar_i32 = _spec((), jnp.int32)
    kv = _spec(kv_shape)
    return {
        "vision_encoder": (
            lambda image: (M.vision_encoder(w, cfg, image),),
            [("image", img)],
        ),
        "connector": (
            lambda f: (M.connector(w, cfg, f),),
            [("features", feats)],
        ),
        "prefill": (
            lambda pseudo, text_ids: M.prefill(w, cfg, pseudo, text_ids),
            [("pseudo_tokens", feats), ("text_ids", ids)],
        ),
        "decode_step": (
            lambda tok, pos, k, v: M.decode_step(w, cfg, tok, pos, k, v),
            [("token", scalar_i32), ("position", scalar_i32),
             ("k_cache", kv), ("v_cache", kv)],
        ),
        "model": (
            lambda image, text_ids: (M.model_smoke(w, cfg, image, text_ids),),
            [("image", img), ("text_ids", ids)],
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", help="path of the model smoke artifact "
                                  "(directory of --out receives the rest)")
    ap.add_argument("--outdir", help="artifact output directory")
    ap.add_argument("--parity-steps", type=int, default=16,
                    help="greedy steps recorded in the parity oracle")
    args = ap.parse_args()
    outdir = args.outdir or (os.path.dirname(args.out) if args.out else "../artifacts")
    os.makedirs(outdir, exist_ok=True)

    cfg = M.DEFAULT_CONFIG
    w = M.init_weights(cfg)
    entries = build_entry_points(w, cfg)

    manifest = {
        "format": "hlo-text-v1",
        "config": {
            "d_model": cfg.d_model, "n_heads": cfg.n_heads,
            "d_head": cfg.d_head, "n_layers": cfg.n_layers,
            "d_ffn": cfg.d_ffn, "vocab": cfg.vocab,
            "img_size": cfg.img_size, "img_channels": cfg.img_channels,
            "patch": cfg.patch, "n_vis_tokens": cfg.n_vis_tokens,
            "prompt_len": cfg.prompt_len, "max_len": cfg.max_len,
            "prefill_len": cfg.prefill_len, "seed": cfg.seed,
        },
        "entry_points": {},
    }

    for name, (fn, arg_specs) in entries.items():
        specs = [s for _, s in arg_specs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *specs)
        manifest["entry_points"][name] = {
            "file": fname,
            "inputs": _sig(arg_specs),
            "outputs": [{"dtype": str(np.dtype(o.dtype)), "shape": list(o.shape)}
                        for o in outs],
        }
        print(f"wrote {path} ({len(text)} chars)")

    # Parity oracle: deterministic image + prompt -> expected greedy tokens.
    image = M.synthetic_image(cfg)
    toks = M.generate(w, cfg, image, M.DEFAULT_PROMPT, args.parity_steps)
    manifest["parity"] = {
        "image": "synthetic_v1 ((i*W+j)*C+c) % 11 / 11 - 0.5",
        "prompt": [int(t) for t in M.DEFAULT_PROMPT],
        "n_steps": args.parity_steps,
        "expected_tokens": toks,
    }

    mpath = os.path.join(outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}; parity tokens = {toks}")


if __name__ == "__main__":
    main()
