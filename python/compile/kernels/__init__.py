"""CHIME fused near-memory kernels (Paper Table I) as Pallas kernels.

All kernels run interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); each is validated against the pure-jnp oracle in ref.py.
"""

from .attn_stream import fused_attn_stream
from .ffn_act import fused_ffn_act
from .norm import fused_norm
from .qkv_proj import fused_qkv_proj
from . import ref

__all__ = [
    "fused_attn_stream",
    "fused_ffn_act",
    "fused_norm",
    "fused_qkv_proj",
    "ref",
]
