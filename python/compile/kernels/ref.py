"""Pure-jnp reference oracles for the CHIME fused near-memory kernels.

These implement Table I of the paper (FUSED_QKV_PROJ, FUSED_ATTN_STREAM,
FUSED_FFN_ACT, FUSED_NORM) as straightforward dense jnp math. They are the
CORE correctness signal: every Pallas kernel in this package must match its
oracle to float32 tolerance (see python/tests/test_kernels.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Large-negative used instead of -inf so that online-softmax bookkeeping in
# the streaming kernel never produces inf - inf = nan. Fully-masked rows are
# padding and are sliced away by callers.
NEG_INF = -1e30


def qkv_proj_ref(x, wq, bq, wk, bk, wv, bv):
    """FUSED_QKV_PROJ: three GEMMs + bias adds (PE: GEMM -> SFPE: Add)."""
    q = x @ wq + bq
    k = x @ wk + bk
    v = x @ wv + bv
    return q, k, v


def attn_ref(q, k, v, scale, kv_len, causal=False):
    """FUSED_ATTN_STREAM oracle: full (non-streamed) masked softmax attention.

    q: [H, Sq, Dh]; k, v: [H, Skv, Dh]; kv_len: valid prefix of the KV
    buffer (int); causal aligns the query block to the END of the valid
    prefix (position of q row i is kv_len - Sq + i), which covers both
    prefill (Sq == kv_len) and single-token decode (Sq == 1).
    """
    _, sq, _ = q.shape
    skv = k.shape[1]
    s = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    col = jax.lax.broadcasted_iota(jnp.int32, (sq, skv), 1)
    mask = col < kv_len
    if causal:
        row = jax.lax.broadcasted_iota(jnp.int32, (sq, skv), 0) + (kv_len - sq)
        mask = mask & (col <= row)
    s = jnp.where(mask[None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v)


def ffn_ref(x, w1, b1, w2, b2, activation="gelu"):
    """FUSED_FFN_ACT oracle: GEMM -> Add -> ACT -> GEMM -> Add (the fused
    kernel never materializes the intermediate; the oracle does)."""
    h = x @ w1 + b1
    if activation == "gelu":
        h = jax.nn.gelu(h)
    elif activation == "relu":
        h = jax.nn.relu(h)
    elif activation == "silu":
        h = jax.nn.silu(h)
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return h @ w2 + b2


def norm_ref(x, g, b, eps=1e-5):
    """FUSED_NORM oracle: SFPE Reduce -> Normalize -> Scale -> Shift."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * g + b
