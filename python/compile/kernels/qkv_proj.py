"""FUSED_QKV_PROJ Pallas kernel.

Paper Table I:
    PE: GEMM(X . Wq) -> SFPE: Add(bq) -> Q
    PE: GEMM(X . Wk) -> SFPE: Add(bk) -> K^T
    PE: GEMM(X . Wv) -> SFPE: Add(bv) -> V

Hardware mapping (DESIGN.md §3): the grid walks row tiles of X the way the
DRAM-NMP row buffers stream activation tiles into the PE MRFs; the three
projections are fused in one kernel body so Q/K/V never round-trip through
HBM between the GEMM and the bias add (SFPE stage). Weight blocks stay
resident per grid step — the analogue of QKV weights pinned in DRAM MATs.

interpret=True throughout: CPU PJRT cannot execute Mosaic custom-calls;
real-TPU perf is estimated in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile: sized so an X tile + QKV weight panel fit a PU shared-memory
# sized VMEM budget at the functional model dims; padded shapes below keep
# the grid exact.
DEFAULT_ROW_TILE = 64


def _kernel(x_ref, wq_ref, bq_ref, wk_ref, bk_ref, wv_ref, bv_ref,
            q_ref, k_ref, v_ref):
    x = x_ref[...]
    # PE GEMM -> SFPE bias-add, fused per projection; f32 accumulate
    # mirrors the FP16-in / accumulator-out tensor-core design.
    q_ref[...] = jnp.dot(x, wq_ref[...], preferred_element_type=jnp.float32) + bq_ref[...]
    k_ref[...] = jnp.dot(x, wk_ref[...], preferred_element_type=jnp.float32) + bk_ref[...]
    v_ref[...] = jnp.dot(x, wv_ref[...], preferred_element_type=jnp.float32) + bv_ref[...]


def _pad_rows(a, mult):
    s = a.shape[0]
    pad = (-s) % mult
    if pad:
        a = jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
    return a


@functools.partial(jax.jit, static_argnames=("row_tile",))
def fused_qkv_proj(x, wq, bq, wk, bk, wv, bv, *, row_tile=DEFAULT_ROW_TILE):
    """x: [S, D]; wq: [D, Dq]; wk/wv: [D, Dkv]. Returns (q, k, v)."""
    s, d = x.shape
    dq = wq.shape[1]
    dkv = wk.shape[1]
    ts = min(row_tile, s) if s % min(row_tile, s) == 0 else s
    xp = _pad_rows(x, ts)
    sp = xp.shape[0]
    grid = (sp // ts,)
    full = lambda cols: pl.BlockSpec((d, cols), lambda i: (0, 0))
    bias = lambda cols: pl.BlockSpec((cols,), lambda i: (0,))
    row = lambda cols: pl.BlockSpec((ts, cols), lambda i: (i, 0))
    q, k, v = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[row(d), full(dq), bias(dq), full(dkv), bias(dkv), full(dkv), bias(dkv)],
        out_specs=[row(dq), row(dkv), row(dkv)],
        out_shape=[
            jax.ShapeDtypeStruct((sp, dq), jnp.float32),
            jax.ShapeDtypeStruct((sp, dkv), jnp.float32),
            jax.ShapeDtypeStruct((sp, dkv), jnp.float32),
        ],
        interpret=True,
    )(xp, wq, bq, wk, bk, wv, bv)
    return q[:s], k[:s], v[:s]
