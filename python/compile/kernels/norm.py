"""FUSED_NORM Pallas kernel.

Paper Table I:
    SFPE: Reduce -> Normalize -> Scale (x g) -> Shift (+ b) -> Out

LayerNorm executed entirely in the SFPE lane (256-way SIMD in the paper's
DRAM-NMP): one row tile per grid step, reductions along the feature axis,
no write-back of the centered intermediate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 64


def _make_kernel(eps):
    def kernel(x_ref, g_ref, b_ref, o_ref):
        x = x_ref[...]
        mean = jnp.mean(x, axis=-1, keepdims=True)            # SFPE: Reduce
        var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps)                        # Normalize
        o_ref[...] = (x - mean) * inv * g_ref[...] + b_ref[...]  # Scale+Shift

    return kernel


@functools.partial(jax.jit, static_argnames=("eps", "row_tile"))
def fused_norm(x, g, b, *, eps=1e-5, row_tile=DEFAULT_ROW_TILE):
    """x: [S, D]; g, b: [D]. Returns LayerNorm(x) * g + b."""
    s, d = x.shape
    ts = min(row_tile, s) if s % min(row_tile, s) == 0 else s
    pad = (-s) % ts
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    sp = xp.shape[0]
    out = pl.pallas_call(
        _make_kernel(eps),
        grid=(sp // ts,),
        in_specs=[
            pl.BlockSpec((ts, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ts, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, d), jnp.float32),
        interpret=True,
    )(xp, g, b)
    return out[:s]
