"""FUSED_ATTN_STREAM Pallas kernel — streaming online-softmax attention.

Paper Table I:
    for each tile (K_t^T, V_t):
        PE: GEMM(Q . K_t^T) -> SFPE: OnlineSoftmaxUpdate
        -> PE: GEMM(Scores_t . V_t) with accumulate -> Out

This is the paper's FlashAttention-style DRAM-NMP kernel: the attention
score matrix is never materialized; each KV tile streams from the KV-cache
tiers through the PE (GEMM) -> SFPE (online softmax) -> PE (GEMM-accumulate)
pipeline, with the running max / running sum / accumulator living in the PU
shared memory (here: the fori_loop carry in VMEM-resident values).

Masking supports both phases of the two-cut-point dataflow:
  * kv_len masks the valid prefix of a fixed-capacity KV buffer (decode
    steps append at position kv_len-1);
  * causal aligns the query block to the END of the prefix (query row i is
    global position kv_len - Sq + i), covering prefill and decode with one
    kernel, exactly as the mapping framework reuses one fused kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

# KV tile ("row-buffer burst") size. 64 keeps the tile MXU/lane aligned
# while staying small enough that padded tiny-model buffers stay exact.
DEFAULT_KV_TILE = 64


def _make_kernel(scale, causal, sq, dh, skv, kv_tile):
    n_tiles = skv // kv_tile

    def kernel(len_ref, q_ref, k_ref, v_ref, o_ref):
        kv_len = len_ref[0, 0]
        q = q_ref[0]  # [Sq, Dh]

        def body(t, carry):
            m, l, acc = carry
            kt = k_ref[0, pl.ds(t * kv_tile, kv_tile), :]  # [Tk, Dh]
            vt = v_ref[0, pl.ds(t * kv_tile, kv_tile), :]
            # PE: GEMM(Q . K_t^T)
            s = jnp.dot(q, kt.T, preferred_element_type=jnp.float32) * scale
            col = t * kv_tile + jax.lax.broadcasted_iota(jnp.int32, (sq, kv_tile), 1)
            mask = col < kv_len
            if causal:
                row = jax.lax.broadcasted_iota(jnp.int32, (sq, kv_tile), 0) + (kv_len - sq)
                mask = mask & (col <= row)
            s = jnp.where(mask, s, NEG_INF)
            # SFPE: OnlineSoftmaxUpdate
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            # PE: GEMM(Scores_t . V_t) with accumulate
            acc_new = acc * alpha + jnp.dot(p, vt, preferred_element_type=jnp.float32)
            return m_new, l_new, acc_new

        m0 = jnp.full((sq, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((sq, 1), jnp.float32)
        acc0 = jnp.zeros((sq, dh), jnp.float32)
        m, l, acc = jax.lax.fori_loop(0, n_tiles, body, (m0, l0, acc0))
        o_ref[0] = acc / jnp.maximum(l, 1e-30)

    return kernel


def _pad_axis(a, axis, mult):
    pad = (-a.shape[axis]) % mult
    if pad:
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, pad)
        a = jnp.pad(a, widths)
    return a


@functools.partial(jax.jit, static_argnames=("scale", "causal", "kv_tile"))
def fused_attn_stream(q, k, v, kv_len, *, scale, causal=False,
                      kv_tile=DEFAULT_KV_TILE):
    """q: [H, Sq, Dh]; k, v: [H, Skv, Dh]; kv_len: int32 valid KV prefix.

    Returns [H, Sq, Dh]. Rows beyond the causal-valid region are padding
    garbage only if the caller passes padded queries; real rows always
    attend to >= 1 column.
    """
    h, sq, dh = q.shape
    skv = k.shape[1]
    tk = min(kv_tile, skv) if skv % min(kv_tile, skv) == 0 else skv
    kp = _pad_axis(k, 1, tk)
    vp = _pad_axis(v, 1, tk)
    skv_p = kp.shape[1]
    kv_len_arr = jnp.asarray(kv_len, jnp.int32).reshape(1, 1)
    kernel = _make_kernel(scale, causal, sq, dh, skv_p, tk)
    out = pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, sq, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, skv_p, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, skv_p, dh), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, sq, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq, dh), jnp.float32),
        interpret=True,
    )(kv_len_arr, q, kp, vp)
    return out
