"""FUSED_FFN_ACT Pallas kernel.

Paper Table I:
    PE: GEMM(X . W1) -> Add(b1) -> ACT -> PE: GEMM(Y . W2) -> SFPE: Add(b2)

This is the RRAM-NMP kernel: both GEMMs chain inside one kernel body so the
intermediate activation Y never leaves the logic die (the paper's 1 MB
PU SRAM; here the VMEM-resident temporary). W1/W2 play the role of weights
resident in the stacked RRAM arrays — their BlockSpecs pin the full weight
panel per grid step, and only the activation row tile streams.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 64

_ACTS = {
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "silu": jax.nn.silu,
}


def _make_kernel(activation):
    act = _ACTS[activation]

    def kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
        # PE: GEMM -> SFPE: Add -> SFPE: ACT, intermediate stays local.
        y = act(jnp.dot(x_ref[...], w1_ref[...],
                        preferred_element_type=jnp.float32) + b1_ref[...])
        # PE: GEMM -> SFPE: Add -> Out (streams back over the cut point).
        o_ref[...] = jnp.dot(y, w2_ref[...],
                             preferred_element_type=jnp.float32) + b2_ref[...]

    return kernel


def _pad_rows(a, mult):
    pad = (-a.shape[0]) % mult
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
    return a


@functools.partial(jax.jit, static_argnames=("activation", "row_tile"))
def fused_ffn_act(x, w1, b1, w2, b2, *, activation="gelu",
                  row_tile=DEFAULT_ROW_TILE):
    """x: [S, D]; w1: [D, F]; w2: [F, Dout]. Returns [S, Dout]."""
    s, d = x.shape
    f = w1.shape[1]
    dout = w2.shape[1]
    ts = min(row_tile, s) if s % min(row_tile, s) == 0 else s
    xp = _pad_rows(x, ts)
    sp = xp.shape[0]
    out = pl.pallas_call(
        _make_kernel(activation),
        grid=(sp // ts,),
        in_specs=[
            pl.BlockSpec((ts, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, dout), lambda i: (0, 0)),
            pl.BlockSpec((dout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ts, dout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, dout), jnp.float32),
        interpret=True,
    )(xp, w1, b1, w2, b2)
    return out[:s]
