"""Kernel-vs-oracle correctness: every Table I fused Pallas kernel must
match its pure-jnp reference (ref.py) to float32 tolerance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import (fused_attn_stream, fused_ffn_act, fused_norm,
                             fused_qkv_proj, ref)

ATOL = 2e-5
RTOL = 2e-5


def _rand(key, *shape, scale=1.0):
    return jax.random.normal(key, shape) * scale


def _keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def assert_close(a, b, atol=ATOL, rtol=RTOL):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=atol, rtol=rtol)


# ---------------------------------------------------------------------------
# FUSED_QKV_PROJ
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,d,dkv", [
    (1, 8, 8), (7, 16, 8), (32, 64, 64), (33, 64, 16), (128, 32, 24),
])
def test_qkv_proj_matches_ref(s, d, dkv):
    ks = _keys(s * d + dkv, 7)
    x = _rand(ks[0], s, d)
    wq, bq = _rand(ks[1], d, d, scale=0.2), _rand(ks[2], d, scale=0.1)
    wk, bk = _rand(ks[3], d, dkv, scale=0.2), _rand(ks[4], dkv, scale=0.1)
    wv, bv = _rand(ks[5], d, dkv, scale=0.2), _rand(ks[6], dkv, scale=0.1)
    got = fused_qkv_proj(x, wq, bq, wk, bk, wv, bv)
    want = ref.qkv_proj_ref(x, wq, bq, wk, bk, wv, bv)
    for g, w in zip(got, want):
        assert_close(g, w)


def test_qkv_proj_row_tiling_invariance():
    """Different row tiles must not change the numbers (pure schedule)."""
    ks = _keys(0, 7)
    s, d = 48, 32
    x = _rand(ks[0], s, d)
    args = (x, _rand(ks[1], d, d), _rand(ks[2], d), _rand(ks[3], d, d),
            _rand(ks[4], d), _rand(ks[5], d, d), _rand(ks[6], d))
    a = fused_qkv_proj(*args, row_tile=16)
    b = fused_qkv_proj(*args, row_tile=48)
    for x1, x2 in zip(a, b):
        assert_close(x1, x2, atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# FUSED_ATTN_STREAM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,sq,skv,dh,kv_len,causal", [
    (1, 1, 8, 8, 8, False),
    (4, 16, 16, 16, 16, True),          # prefill: square causal
    (4, 1, 64, 16, 33, False),          # decode: 1 query over prefix
    (2, 8, 40, 8, 24, True),            # causal block at end of prefix
    (8, 32, 128, 32, 128, True),
    (3, 5, 21, 8, 13, False),           # ragged everything
])
def test_attn_stream_matches_ref(h, sq, skv, dh, kv_len, causal):
    ks = _keys(h * skv + sq, 3)
    q = _rand(ks[0], h, sq, dh)
    k = _rand(ks[1], h, skv, dh)
    v = _rand(ks[2], h, skv, dh)
    scale = 1.0 / np.sqrt(dh)
    got = fused_attn_stream(q, k, v, kv_len, scale=scale, causal=causal,
                            kv_tile=8)
    want = ref.attn_ref(q, k, v, scale, kv_len, causal=causal)
    assert_close(got, want)


def test_attn_stream_tile_invariance():
    """Streaming tile size is a schedule knob, not a numeric one."""
    ks = _keys(7, 3)
    q, k, v = (_rand(ks[0], 2, 8, 16), _rand(ks[1], 2, 64, 16),
               _rand(ks[2], 2, 64, 16))
    outs = [np.asarray(fused_attn_stream(q, k, v, 50, scale=0.25,
                                         causal=True, kv_tile=t))
            for t in (8, 16, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=1e-5, rtol=1e-5)


def test_attn_stream_kv_len_masks_tail():
    """Entries beyond kv_len must not influence the output (KV-cache
    append-only discipline: garbage past the valid prefix is invisible)."""
    ks = _keys(11, 3)
    q = _rand(ks[0], 2, 1, 8)
    k = _rand(ks[1], 2, 32, 8)
    v = _rand(ks[2], 2, 32, 8)
    base = fused_attn_stream(q, k, v, 10, scale=0.5)
    k_dirty = k.at[:, 10:].set(1e6)
    v_dirty = v.at[:, 10:].set(-1e6)
    dirty = fused_attn_stream(q, k_dirty, v_dirty, 10, scale=0.5)
    assert_close(base, dirty, atol=1e-6, rtol=1e-6)


def test_attn_stream_causal_blocks_future():
    """Row i must ignore columns > i when causal (prefill semantics)."""
    ks = _keys(13, 3)
    s = 12
    q = _rand(ks[0], 1, s, 8)
    k = _rand(ks[1], 1, s, 8)
    v = _rand(ks[2], 1, s, 8)
    full = fused_attn_stream(q, k, v, s, scale=0.3, causal=True)
    # Recompute each row with only its visible prefix: must agree.
    for i in (0, 3, s - 1):
        pre = fused_attn_stream(q[:, i:i + 1], k[:, :i + 1], v[:, :i + 1],
                                i + 1, scale=0.3, causal=False)
        assert_close(full[:, i:i + 1], pre, atol=1e-5, rtol=1e-5)


def test_attn_stream_uniform_when_keys_equal():
    """Equal keys -> uniform weights -> output = mean of valid values."""
    h, skv, dh = 2, 16, 8
    q = jnp.ones((h, 1, dh))
    k = jnp.ones((h, skv, dh))
    v = jnp.arange(h * skv * dh, dtype=jnp.float32).reshape(h, skv, dh)
    out = fused_attn_stream(q, k, v, 8, scale=1.0)
    want = v[:, :8].mean(axis=1, keepdims=True)
    assert_close(out, want)


# ---------------------------------------------------------------------------
# FUSED_FFN_ACT
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,d,f", [(1, 8, 16), (16, 64, 256), (33, 32, 48)])
@pytest.mark.parametrize("act", ["gelu", "relu", "silu"])
def test_ffn_act_matches_ref(s, d, f, act):
    ks = _keys(s + d + f, 5)
    x = _rand(ks[0], s, d)
    w1, b1 = _rand(ks[1], d, f, scale=0.2), _rand(ks[2], f, scale=0.1)
    w2, b2 = _rand(ks[3], f, d, scale=0.2), _rand(ks[4], d, scale=0.1)
    got = fused_ffn_act(x, w1, b1, w2, b2, activation=act)
    want = ref.ffn_ref(x, w1, b1, w2, b2, activation=act)
    assert_close(got, want)


def test_ffn_act_rectangular_out():
    ks = _keys(5, 5)
    x = _rand(ks[0], 8, 16)
    w1, b1 = _rand(ks[1], 16, 32), _rand(ks[2], 32)
    w2, b2 = _rand(ks[3], 32, 24), _rand(ks[4], 24)
    got = fused_ffn_act(x, w1, b1, w2, b2)
    assert got.shape == (8, 24)
    assert_close(got, ref.ffn_ref(x, w1, b1, w2, b2))


# ---------------------------------------------------------------------------
# FUSED_NORM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,d", [(1, 8), (16, 64), (33, 32), (128, 16)])
def test_norm_matches_ref(s, d):
    ks = _keys(s * d, 3)
    x = _rand(ks[0], s, d, scale=3.0)
    g = _rand(ks[1], d) + 1.0
    b = _rand(ks[2], d)
    assert_close(fused_norm(x, g, b), ref.norm_ref(x, g, b))


def test_norm_output_is_normalized():
    x = _rand(_keys(1, 1)[0], 8, 64, scale=10.0) + 5.0
    out = np.asarray(fused_norm(x, jnp.ones(64), jnp.zeros(64)))
    np.testing.assert_allclose(out.mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(out.std(axis=-1), 1.0, atol=1e-2)


def test_norm_scale_shift_applied():
    x = _rand(_keys(2, 1)[0], 4, 16)
    g = jnp.full(16, 2.0)
    b = jnp.full(16, 0.5)
    base = np.asarray(fused_norm(x, jnp.ones(16), jnp.zeros(16)))
    scaled = np.asarray(fused_norm(x, g, b))
    np.testing.assert_allclose(scaled, base * 2.0 + 0.5, atol=1e-5)
