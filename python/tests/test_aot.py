"""AOT artifact tests: lowering succeeds, HLO text is id-safe, manifest is
consistent with the model config."""

import json
import os

import jax
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def entries():
    cfg = M.DEFAULT_CONFIG
    w = M.init_weights(cfg)
    return aot.build_entry_points(w, cfg), cfg


def test_entry_points_complete(entries):
    eps, _ = entries
    assert set(eps) == {"vision_encoder", "connector", "prefill",
                        "decode_step", "model"}


@pytest.mark.parametrize("name", ["connector", "decode_step"])
def test_lowering_produces_parseable_hlo_text(entries, name):
    eps, _ = entries
    fn, arg_specs = eps[name]
    lowered = jax.jit(fn).lower(*[s for _, s in arg_specs])
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # The whole point of the text interchange: no 64-bit-id proto issues,
    # and the entry computation returns a tuple (return_tuple=True).
    assert "tuple" in text or ")" in text


def test_manifest_artifacts_on_disk():
    """If `make artifacts` has run, the manifest must agree with the files
    and the model config (skipped otherwise — pytest runs pre-artifact in
    some CI orders)."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(root, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built yet")
    with open(mpath) as f:
        man = json.load(f)
    assert man["format"] == "hlo-text-v1"
    cfg = M.DEFAULT_CONFIG
    assert man["config"]["d_model"] == cfg.d_model
    assert man["config"]["seed"] == cfg.seed
    assert man["config"]["prefill_len"] == cfg.prefill_len
    for name, ep in man["entry_points"].items():
        path = os.path.join(root, ep["file"])
        assert os.path.exists(path), f"missing artifact {name}"
        with open(path) as f:
            head = f.read(64)
        assert head.startswith("HloModule")
    parity = man["parity"]
    assert len(parity["expected_tokens"]) == parity["n_steps"]
    assert parity["prompt"] == [int(t) for t in M.DEFAULT_PROMPT]


def test_parity_tokens_match_live_model():
    """Manifest parity oracle must reproduce from source (guards stale
    artifacts after model edits)."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(root, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built yet")
    with open(mpath) as f:
        man = json.load(f)
    if man["config"]["seed"] != M.DEFAULT_CONFIG.seed:
        pytest.skip("artifacts built from a different seed")
    cfg = M.DEFAULT_CONFIG
    w = M.init_weights(cfg)
    n = min(4, man["parity"]["n_steps"])  # a prefix is enough, keeps CI fast
    toks = M.generate(w, cfg, M.synthetic_image(cfg), M.DEFAULT_PROMPT, n)
    assert toks == man["parity"]["expected_tokens"][:n]
