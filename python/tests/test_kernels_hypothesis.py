"""Hypothesis sweeps over the Pallas kernels' shape/parameter space.

Property: for every valid shape/dtype draw, the fused kernel equals the
pure-jnp oracle (ref.py) — the invariant that makes the streaming/fusion
schedule a pure performance transform.
"""

import pytest

# Belt-and-braces with conftest's collection gate: a direct invocation of
# this file on a machine without hypothesis must skip, not error.
pytest.importorskip("hypothesis", reason="hypothesis not installed")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import (fused_attn_stream, fused_ffn_act, fused_norm,
                             fused_qkv_proj, ref)

SETTINGS = dict(max_examples=25, deadline=None)


def _arr(seed, *shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


@settings(**SETTINGS)
@given(
    s=st.integers(1, 96),
    d=st.sampled_from([8, 16, 32, 64]),
    dkv=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
    row_tile=st.sampled_from([8, 16, 32, 64]),
)
def test_qkv_proj_property(s, d, dkv, seed, row_tile):
    x = _arr(seed, s, d)
    wq, bq = _arr(seed + 1, d, d, scale=0.2), _arr(seed + 2, d, scale=0.1)
    wk, bk = _arr(seed + 3, d, dkv, scale=0.2), _arr(seed + 4, dkv, scale=0.1)
    wv, bv = _arr(seed + 5, d, dkv, scale=0.2), _arr(seed + 6, dkv, scale=0.1)
    got = fused_qkv_proj(x, wq, bq, wk, bk, wv, bv, row_tile=row_tile)
    want = ref.qkv_proj_ref(x, wq, bq, wk, bk, wv, bv)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=3e-5, rtol=3e-5)


@settings(**SETTINGS)
@given(
    h=st.integers(1, 6),
    sq=st.integers(1, 24),
    extra_kv=st.integers(0, 40),
    dh=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
    kv_tile=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**16),
)
def test_attn_stream_property(h, sq, extra_kv, dh, causal, kv_tile, seed):
    # kv_len >= sq so every (causal) query row sees >= 1 valid column.
    kv_len = sq + extra_kv
    skv = kv_len + (seed % 5)  # buffer may exceed the valid prefix
    q = _arr(seed, h, sq, dh)
    k = _arr(seed + 1, h, skv, dh)
    v = _arr(seed + 2, h, skv, dh)
    scale = 1.0 / np.sqrt(dh)
    got = fused_attn_stream(q, k, v, kv_len, scale=scale, causal=causal,
                            kv_tile=kv_tile)
    want = ref.attn_ref(q, k, v, scale, kv_len, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@settings(**SETTINGS)
@given(
    s=st.integers(1, 64),
    d=st.sampled_from([8, 16, 64]),
    f=st.sampled_from([16, 48, 128]),
    act=st.sampled_from(["gelu", "relu", "silu"]),
    seed=st.integers(0, 2**16),
)
def test_ffn_act_property(s, d, f, act, seed):
    x = _arr(seed, s, d)
    w1, b1 = _arr(seed + 1, d, f, scale=0.2), _arr(seed + 2, f, scale=0.1)
    w2, b2 = _arr(seed + 3, f, d, scale=0.2), _arr(seed + 4, d, scale=0.1)
    got = fused_ffn_act(x, w1, b1, w2, b2, activation=act)
    want = ref.ffn_ref(x, w1, b1, w2, b2, activation=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@settings(**SETTINGS)
@given(
    s=st.integers(1, 80),
    d=st.sampled_from([8, 16, 64, 128]),
    scale=st.floats(0.1, 10.0),
    seed=st.integers(0, 2**16),
)
def test_norm_property(s, d, scale, seed):
    x = _arr(seed, s, d, scale=scale)
    g = _arr(seed + 1, d) + 1.0
    b = _arr(seed + 2, d)
    got = fused_norm(x, g, b)
    want = ref.norm_ref(x, g, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)
