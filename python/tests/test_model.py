"""L2 model tests: shapes, KV-cache semantics, prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def cfg():
    return M.DEFAULT_CONFIG


@pytest.fixture(scope="module")
def weights(cfg):
    return M.init_weights(cfg)


@pytest.fixture(scope="module")
def image(cfg):
    return jnp.asarray(M.synthetic_image(cfg))


def test_synthetic_image_deterministic(cfg):
    a = M.synthetic_image(cfg)
    b = M.synthetic_image(cfg)
    assert a.shape == (cfg.img_size, cfg.img_size, cfg.img_channels)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= -0.5 and a.max() <= 0.5


def test_vision_encoder_shape(weights, cfg, image):
    feats = M.vision_encoder(weights, cfg, image)
    assert feats.shape == (cfg.n_vis_tokens, cfg.d_model)
    assert np.isfinite(np.asarray(feats)).all()


def test_connector_shape(weights, cfg, image):
    feats = M.vision_encoder(weights, cfg, image)
    pseudo = M.connector(weights, cfg, feats)
    assert pseudo.shape == (cfg.n_vis_tokens, cfg.d_model)


def test_prefill_outputs(weights, cfg, image):
    feats = M.vision_encoder(weights, cfg, image)
    pseudo = M.connector(weights, cfg, feats)
    logits, k, v = M.prefill(weights, cfg, pseudo, jnp.asarray(M.DEFAULT_PROMPT))
    assert logits.shape == (cfg.vocab,)
    assert k.shape == (cfg.n_layers, cfg.n_heads, cfg.max_len, cfg.d_head)
    assert v.shape == k.shape
    # KV beyond the prefill length must be untouched zeros.
    s = cfg.prefill_len
    np.testing.assert_array_equal(np.asarray(k[:, :, s:]), 0.0)
    np.testing.assert_array_equal(np.asarray(v[:, :, s:]), 0.0)
    # ... and the filled prefix must not be all zeros.
    assert np.abs(np.asarray(k[:, :, :s])).max() > 0


def test_decode_appends_at_position(weights, cfg, image):
    feats = M.vision_encoder(weights, cfg, image)
    pseudo = M.connector(weights, cfg, feats)
    _, k0, v0 = M.prefill(weights, cfg, pseudo, jnp.asarray(M.DEFAULT_PROMPT))
    pos = cfg.prefill_len
    _, k1, v1 = M.decode_step(weights, cfg, jnp.asarray(7, jnp.int32),
                              jnp.asarray(pos, jnp.int32), k0, v0)
    # prefix untouched
    np.testing.assert_allclose(np.asarray(k1[:, :, :pos]),
                               np.asarray(k0[:, :, :pos]))
    # slot `pos` written
    assert np.abs(np.asarray(k1[:, :, pos])).max() > 0
    # tail still zero
    np.testing.assert_array_equal(np.asarray(k1[:, :, pos + 1:]), 0.0)
    np.testing.assert_array_equal(np.asarray(v1[:, :, pos + 1:]), 0.0)


def test_decode_matches_recomputed_prefill(weights, cfg, image):
    """Incremental decode must equal recomputing the full sequence: the
    KV-cache path is a pure optimization (paper's append-only discipline)."""
    feats = M.vision_encoder(weights, cfg, image)
    pseudo = M.connector(weights, cfg, feats)
    prompt = jnp.asarray(M.DEFAULT_PROMPT)
    logits_p, k, v = M.prefill(weights, cfg, pseudo, prompt)
    tok = int(jnp.argmax(logits_p))
    logits_d, _, _ = M.decode_step(weights, cfg, jnp.asarray(tok, jnp.int32),
                                   jnp.asarray(cfg.prefill_len, jnp.int32), k, v)

    # Recompute: run prefill over prompt + [tok] by extending the pseudo/text
    # input through the non-cached path.
    s = cfg.prefill_len + 1
    x = jnp.concatenate([pseudo, weights["emb"][prompt],
                         weights["emb"][jnp.asarray([tok])]], axis=0)
    x = x + weights["pos"][:s]
    for lw in weights["llm_layers"]:
        x = M._attn_block(x, lw, cfg, kv_len=s, causal=True)
        x = M._ffn_block(x, lw)
    want = M._logits(weights, x[-1])
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_generate_deterministic(weights, cfg, image):
    a = M.generate(weights, cfg, image, M.DEFAULT_PROMPT, 6)
    b = M.generate(weights, cfg, image, M.DEFAULT_PROMPT, 6)
    assert a == b
    assert len(a) == 6
    assert all(0 <= t < cfg.vocab for t in a)


def test_generate_depends_on_image(weights, cfg, image):
    """The visual pathway must influence generation (multimodality is real,
    not a dead input)."""
    other = jnp.asarray(np.ones_like(np.asarray(image)) * 0.5)
    feats_a = M.vision_encoder(weights, cfg, image)
    feats_b = M.vision_encoder(weights, cfg, other)
    assert np.abs(np.asarray(feats_a) - np.asarray(feats_b)).max() > 1e-3
    la, _, _ = M.prefill(weights, cfg, M.connector(weights, cfg, feats_a),
                         jnp.asarray(M.DEFAULT_PROMPT))
    lb, _, _ = M.prefill(weights, cfg, M.connector(weights, cfg, feats_b),
                         jnp.asarray(M.DEFAULT_PROMPT))
    assert np.abs(np.asarray(la) - np.asarray(lb)).max() > 1e-4


def test_model_smoke_equals_pipeline(weights, cfg, image):
    """model.hlo.txt's fused graph must equal the staged pipeline."""
    smoke = M.model_smoke(weights, cfg, image, jnp.asarray(M.DEFAULT_PROMPT))
    feats = M.vision_encoder(weights, cfg, image)
    pseudo = M.connector(weights, cfg, feats)
    staged, _, _ = M.prefill(weights, cfg, pseudo, jnp.asarray(M.DEFAULT_PROMPT))
    np.testing.assert_allclose(np.asarray(smoke), np.asarray(staged),
                               atol=1e-5, rtol=1e-5)
