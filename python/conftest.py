"""Pytest bootstrap for the python/ tree.

Two jobs:

1. Make ``compile.*`` importable regardless of invocation directory.
2. Gate collection on the optional toolchain: the kernel/model/AOT tests
   need JAX (the AOT/Pallas toolchain) and the property sweep additionally
   needs ``hypothesis``. When a requirement is absent the corresponding
   module is *skipped with a reason* (reported in the session header)
   instead of erroring at collection, so ``pytest`` stays green on
   machines that only carry the Rust side.
"""
import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def _missing(mod):
    try:
        return importlib.util.find_spec(mod) is None
    except (ImportError, ValueError):
        return True


# Test module -> import requirements beyond pytest itself.
_REQUIREMENTS = {
    "test_aot.py": ["jax", "numpy"],
    "test_model.py": ["jax", "numpy"],
    "test_kernels.py": ["jax", "numpy"],
    "test_kernels_hypothesis.py": ["jax", "numpy", "hypothesis"],
}


def pytest_ignore_collect(collection_path, config):
    name = os.path.basename(str(collection_path))
    reqs = _REQUIREMENTS.get(name, [])
    if any(_missing(r) for r in reqs):
        return True
    return None


def pytest_report_header(config):
    lines = []
    for name, reqs in sorted(_REQUIREMENTS.items()):
        gone = sorted({r for r in reqs if _missing(r)})
        if gone:
            lines.append(
                "chime: skipping %s (missing: %s)" % (name, ", ".join(gone))
            )
    return lines
